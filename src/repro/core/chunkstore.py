"""Chunk-addressed component storage — the live delta-fetch layer.

``LocalComponentStore`` dedups at *component* granularity: a version bump
re-fetches the whole component even though most of its content is unchanged.
This module makes the paper's chunk-level sharing (Table 1) the live
storage/fetch path: every component is split into deterministic content
chunks (``repro.core.store.component_pieces`` — a stable fraction keyed by
``(manager, name, index)`` only, identical across versions and environment
variants), presence is tracked per chunk, and the fetch planner charges only
the chunks that are neither present nor already in flight.

Concurrency model (what ``FleetDeployer`` relies on):

  * ``plan_fetch`` atomically registers the component and *claims* its
    missing chunks under the store lock.  A claimed chunk is "in flight":
    any other build planning the same chunk — even mid-transfer — gets a
    wait handle instead of a second charge (singleflight dedup).
  * ``commit_chunks`` marks claimed chunks present and releases waiters.
  * ``abort_chunks`` releases a failed claim without marking it present, so
    one build's fetch error never wedges another build's pipeline.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .component import UniformComponent
from .store import (Chunk, LocalComponentStore, SHARED_PIECE_FRACTION,
                    component_pieces)

# Live chunk granularity.  The Table-1 *study* granularity is 64 KiB; the
# live store defaults to 4 MiB (OCI/estargz-scale blob chunking) so that
# multi-GB weight assets stay at thousands — not millions — of bookkeeping
# entries per build.
DEFAULT_CHUNK_SIZE = 4 * 2**20

# A claim is released by commit/abort in the claiming thread; the timeout is
# only a backstop against a claimer dying without either (e.g. interpreter
# teardown), so waiters degrade to a free hit instead of deadlocking.
CLAIM_WAIT_TIMEOUT_S = 60.0


@dataclasses.dataclass
class ChunkStats:
    """Chunk-level accounting on top of the component-level ``StoreStats``."""
    chunks_stored: int = 0
    chunks_hit: int = 0
    chunks_missed: int = 0
    chunks_waited: int = 0          # singleflight: in flight elsewhere
    chunk_bytes_stored: int = 0     # RESIDENT unique chunk bytes (capacity
    #                                 evictions decrement; == committed on
    #                                 an unbounded store)
    chunk_bytes_requested: int = 0  # new-component bytes before chunk dedup
    chunk_bytes_evicted: int = 0    # bytes dropped by capacity eviction —
    #                                 they DID cross the wire when committed
    corrupt_rejected: int = 0       # peer-received chunks failing the
    #                                 verify-on-receipt digest check (§12) —
    #                                 discarded before commit, never resident

    @property
    def delta_sharing_rate(self) -> float:
        """Fraction of new-component bytes the chunk layer did NOT transfer
        — the savings on top of component-level dedup.  Transfer cost is
        resident + evicted bytes (eviction does not un-transfer anything);
        floored at 0 for churn so heavy that re-fetches exceed the savings.
        """
        if self.chunk_bytes_requested == 0:
            return 0.0
        transferred = self.chunk_bytes_stored + self.chunk_bytes_evicted
        return max(0.0, 1.0 - transferred / self.chunk_bytes_requested)

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["delta_sharing_rate"] = self.delta_sharing_rate
        return d


@dataclasses.dataclass
class FetchPlan:
    """The missing-chunk plan for one component of one build.

    ``claimed`` chunks are this build's to fetch (and charge); ``hits`` are
    already present; ``waits`` are in flight under another build's claim —
    free for this build, but not yet usable until the event fires.
    ``barriers`` are the outstanding transfer events of a component-level
    hit whose first build is still mid-flight: nothing to charge, but the
    content is not complete until they fire.  ``rescan`` marks a repair
    re-plan of a digest a previous build left incomplete — accounted as a
    miss, since it does real transfer work.
    """
    component: UniformComponent
    component_new: bool
    hits: List[Chunk]
    claimed: List[Tuple[Chunk, threading.Event]]
    waits: List[Tuple[Chunk, threading.Event]]
    barriers: List[threading.Event] = dataclasses.field(default_factory=list)
    rescan: bool = False

    @property
    def bytes_hit(self) -> int:
        return sum(ch.size for ch in self.hits) + \
            sum(ch.size for ch, _ in self.waits)

    @property
    def bytes_claimed(self) -> int:
        return sum(ch.size for ch, _ in self.claimed)


class ChunkedComponentStore(LocalComponentStore):
    """Content-addressed store with live chunk-level delta accounting.

    Component-level semantics (``put`` hit/miss, ``StoreStats``) are
    unchanged — chunk presence and singleflight claims are layered on, so a
    version-bumped component is a component-level miss whose *wire* cost is
    only its unshared chunks.

    Lifecycle (capacity-bounded stores): ``capacity_bytes`` bounds the
    resident chunk bytes (``chunk_stats.chunk_bytes_stored`` — evictions
    decrement it, so it is the *resident* figure).  Eviction runs when a
    commit pushes the store over budget, in policy order (LRU, or
    ``cheapest-to-restore`` which prefers chunks the ``peer_probe`` hook
    says a linked peer still holds), and **never** touches pinned (build
    lease, see ``acquire_build_lease``) or in-flight-claimed chunks.  Every
    ``eviction_listeners`` callback fires — under the store lock — *before*
    the bytes are dropped, so a peering layer can retract its ``PeerIndex``
    announcements while the content is still present (the never-over-claim
    invariant); listeners must not call back into this store.  Evicting a
    chunk marks every component referencing it incomplete (the next plan of
    that digest re-scans and accounts the re-fetch as a miss, so
    ``delta <= fetched`` survives churn), and a component whose every chunk
    was evicted is GC'd entirely — the next build of it is a plain miss.
    """

    def __init__(self, path: Optional[str] = None,
                 chunk_size: int = DEFAULT_CHUNK_SIZE,
                 shared_fraction: float = SHARED_PIECE_FRACTION,
                 capacity_bytes: Optional[int] = None,
                 eviction_policy: str = "lru"):
        self.chunk_size = chunk_size
        self.shared_fraction = shared_fraction
        # insertion/recency order IS the LRU order: plan hits and commits
        # refresh a chunk's position
        self._chunk_present: "collections.OrderedDict[str, int]" = \
            collections.OrderedDict()
        self._chunk_inflight: Dict[str, threading.Event] = {}
        # component digest -> transfer events outstanding for its content,
        # so a component-level hit can still barrier on a mid-flight fetch
        self._comp_pending: Dict[str, List[threading.Event]] = {}
        # digests registered whose fetch aborted: content is incomplete and
        # the next build of the same digest must re-plan its chunks
        self._incomplete: Set[str] = set()
        # path-backed stores persist a component's JSON only once its
        # content has fully landed — a crash mid-transfer must not reload
        # as present-with-holes.  digest -> component awaiting persistence.
        self._unpersisted: Dict[str, UniformComponent] = {}
        # lifecycle bookkeeping: which components reference which chunks
        # (for incomplete-marking + GC on eviction), chunk pin refcounts
        # (build leases), previously evicted ids (refetch accounting)
        self._chunk_refs: Dict[str, Set[str]] = {}   # chunk id -> digests
        self._comp_chunk_ids: Dict[str, List[str]] = {}
        self._chunk_pins: Dict[str, int] = {}
        self._evicted_ids: Set[str] = set()
        # speculative eviction tier (spec: soft leases, docs §11):
        #   _spec_tier    — chunk id -> spec-lease refcount; members are the
        #                   FIRST eviction victims.  A real demand hit
        #                   *promotes* the chunk out (entry removed outright,
        #                   demand overrides any still-active spec lease).
        #   _spec_unhit   — chunk id -> size for bytes committed
        #                   speculatively and not yet demanded; drained into
        #                   spec_hit_bytes (demand hit) or spec_wasted_bytes
        #                   (evicted first), so hit + wasted <= spec_bytes.
        #   _spec_wait_demand — chunk ids a real build is *waiting* on while
        #                   a speculative transfer is mid-flight; the commit
        #                   counts them as hits immediately (the demand beat
        #                   the speculation by a hair, but the bytes served).
        self._spec_tier: Dict[str, int] = {}
        self._spec_unhit: Dict[str, int] = {}
        self._spec_wait_demand: Set[str] = set()
        # digests GC'd after eviction whose re-registration should count
        # refetch at chunk granularity (only the chunks actually re-claimed
        # cross the wire — plan hits on surviving shared chunks must not
        # inflate the figure)
        self._pending_refetch: Set[str] = set()
        self._chunks_memo: Dict[str, List[Chunk]] = {}
        # advisory callbacks fired (under the store lock) with the chunk ids
        # about to be evicted, BEFORE the bytes are dropped
        self.eviction_listeners: List[Callable[[List[str]], None]] = []
        # cheapest-to-restore oracles: chunk id -> a linked peer holds it.
        # The batch form is preferred — one index snapshot per eviction
        # pass instead of a cross-lock round-trip per resident chunk.
        self.peer_probe: Optional[Callable[[str], bool]] = None
        self.peer_probe_batch: Optional[
            Callable[[Sequence[str]], Set[str]]] = None
        self.chunk_stats = ChunkStats()
        super().__init__(path, capacity_bytes=capacity_bytes,
                         eviction_policy=eviction_policy)
        # components reloaded from disk already hold all their chunks;
        # count them into requested too so delta_sharing_rate stays in
        # [0, 1) across restarts
        for c in self._by_digest.values():
            self.chunk_stats.chunk_bytes_requested += c.size_bytes
            chunks = self.chunks_of(c)
            self._register_refs_locked(c.digest(), chunks)
            for ch in chunks:
                if ch.id not in self._chunk_present:
                    self._chunk_present[ch.id] = ch.size
                    self.chunk_stats.chunks_stored += 1
                    self.chunk_stats.chunk_bytes_stored += ch.size
        with self._lock:
            self._enforce_capacity_locked()

    def chunks_of(self, c: UniformComponent) -> List[Chunk]:
        # memoized per digest: leases + plans re-walk the same components;
        # GIL-atomic get/set (worst case a duplicate compute), entries are
        # dropped when the component is GC'd
        dg = c.digest()
        chunks = self._chunks_memo.get(dg)
        if chunks is None:
            chunks = component_pieces(c, self.chunk_size,
                                      self.shared_fraction)
            self._chunks_memo[dg] = chunks
        return chunks

    def _persist(self, c: UniformComponent) -> None:
        # deferred until the transfer completes (_maybe_persist_locked)
        self._unpersisted[c.digest()] = c

    def _maybe_persist_locked(self, dg: str) -> None:
        """Flush a deferred component JSON once nothing is outstanding for
        its digest and it is not marked incomplete; callers hold _lock."""
        if dg in self._comp_pending or dg in self._incomplete:
            return
        c = self._unpersisted.pop(dg, None)
        if c is not None:
            super()._persist(c)

    def has_chunk(self, chunk_id: str) -> bool:
        with self._lock:
            return chunk_id in self._chunk_present

    def present_chunks(self, chunk_ids: Sequence[str]) -> List[str]:
        """The subset of ``chunk_ids`` resident right now, under one lock
        acquisition — the batch form announcement verification wants (a
        per-id ``has_chunk`` loop would hammer the hot store lock)."""
        with self._lock:
            return [cid for cid in chunk_ids if cid in self._chunk_present]

    def chunk_count(self) -> int:
        with self._lock:
            return len(self._chunk_present)

    def missing_chunks(self, c: UniformComponent) -> List[Chunk]:
        """Chunks of ``c`` not present locally — the proof obligation behind
        a per-component readiness signal (empty == content fully landed).
        Chunking happens outside the lock; the presence check is atomic."""
        chunks = self.chunks_of(c)
        with self._lock:
            return [ch for ch in chunks if ch.id not in self._chunk_present]

    # -- fetch protocol -------------------------------------------------------
    def plan_fetch(self, c: UniformComponent,
                   speculative: bool = False) -> FetchPlan:
        """Atomically register ``c`` and claim its missing chunks.

        For a component already stored (component-level hit) the plan
        charges nothing, but carries barrier events if the build that
        stored it is still transferring — singleflight covers same-digest
        races too.  For a new component, every chunk is classified hit /
        claim / wait under one lock acquisition, so two concurrent builds
        can never both claim (and charge) the same chunk.

        ``speculative`` plans (placement pre-positioning) are not demand:
        they neither refresh LRU recency nor promote chunks out of the
        speculative eviction tier — only a *real* build's plan does.
        """
        dg = c.digest()
        with self._lock:
            probably_stored = dg in self._by_digest \
                and dg not in self._incomplete
        # chunking is one sha256 per chunk — a pure function of the
        # component, computed outside the lock so concurrent builds don't
        # serialize behind a multi-GB asset's hashing.  The warm path
        # (component already stored) skips it entirely.
        chunks = None if probably_stored else self.chunks_of(c)
        with self._lock:
            new = self._put_locked(c)
            hits: List[Chunk] = []
            claimed: List[Tuple[Chunk, threading.Event]] = []
            waits: List[Tuple[Chunk, threading.Event]] = []
            barriers: List[threading.Event] = []
            # an aborted earlier fetch left this digest registered but its
            # content incomplete: re-plan the chunks like a fresh miss
            rescan = not new and dg in self._incomplete
            if rescan:
                self._incomplete.discard(dg)
            if new or rescan:
                if new:
                    self.chunk_stats.chunk_bytes_requested += c.size_bytes
                if chunks is None:     # lost the probe race; rare
                    chunks = self.chunks_of(c)
                self._register_refs_locked(dg, chunks)
                refetch = dg in self._pending_refetch
                self._pending_refetch.discard(dg)
                for ch in chunks:
                    if ch.id in self._chunk_present:
                        hits.append(ch)
                        if not speculative:
                            self._chunk_present.move_to_end(ch.id)  # LRU
                            self._promote_spec_locked(ch.id)
                        self.chunk_stats.chunks_hit += 1
                    elif ch.id in self._chunk_inflight:
                        waits.append((ch, self._chunk_inflight[ch.id]))
                        if not speculative:
                            # a speculative transfer may be what lands this
                            # chunk — record the real demand so the commit
                            # counts it as a hit, not unhit speculation
                            self._spec_wait_demand.add(ch.id)
                        self.chunk_stats.chunks_waited += 1
                    else:
                        ev = threading.Event()
                        self._chunk_inflight[ch.id] = ev
                        claimed.append((ch, ev))
                        self.chunk_stats.chunks_missed += 1
                        if refetch:
                            # a GC'd-after-eviction digest re-entering: its
                            # re-claimed chunks count as refetch on commit
                            self._evicted_ids.add(ch.id)
                pending = [ev for _ch, ev in claimed] + \
                    [ev for _ch, ev in waits]
                if pending:
                    self._comp_pending[dg] = pending
                elif self.path:
                    self._maybe_persist_locked(dg)   # all hits: complete now
            else:
                # a component-level hit is a *use*: on a bounded store its
                # chunks' LRU positions must refresh (the warm path skips
                # chunking, so use the registered id list — no hashing),
                # or eviction would keep targeting the hottest content.
                # Real demand also promotes the chunks out of the
                # speculative tier — a fully pre-positioned component lands
                # on this path, so its speculation-hit accounting does too.
                if not speculative and (self.capacity_bytes is not None
                                        or self._spec_tier
                                        or self._spec_unhit):
                    for cid in self._comp_chunk_ids.get(dg, ()):
                        if cid in self._chunk_present:
                            if self.capacity_bytes is not None:
                                self._chunk_present.move_to_end(cid)
                            self._promote_spec_locked(cid)
                live = [ev for ev in self._comp_pending.get(dg, ())
                        if not ev.is_set()]
                if live:
                    self._comp_pending[dg] = live
                    barriers = live
                else:
                    self._comp_pending.pop(dg, None)
                    if self.path:
                        self._maybe_persist_locked(dg)
            return FetchPlan(component=c, component_new=new, hits=hits,
                             claimed=claimed, waits=waits, barriers=barriers,
                             rescan=rescan)

    def commit_chunks(self,
                      claimed: Sequence[Tuple[Chunk, threading.Event]],
                      component: Optional[UniformComponent] = None,
                      speculative: bool = False
                      ) -> None:
        """Mark fetched chunks present and release their waiters.  With
        ``component`` given, its pending-event record is pruned once no
        outstanding transfers remain (bounds the barrier bookkeeping).

        ``speculative`` commits (placement pre-positioning under a ``spec:``
        soft lease) are accounted in ``lifecycle_stats.spec_bytes`` and the
        chunks join the speculative eviction tier until a real build's plan
        demands them — unless a real build is already *waiting* on the
        transfer, which counts as an immediate speculation hit."""
        batch = {id(ev) for _ch, ev in claimed}
        with self._lock:
            for ch, _ev in claimed:
                self._chunk_present[ch.id] = ch.size
                self._chunk_present.move_to_end(ch.id)   # freshest
                self._chunk_inflight.pop(ch.id, None)
                self.chunk_stats.chunks_stored += 1
                self.chunk_stats.chunk_bytes_stored += ch.size
                if ch.id in self._evicted_ids:
                    self._evicted_ids.discard(ch.id)
                    self.lifecycle_stats.refetch_bytes += ch.size
                if speculative:
                    self.lifecycle_stats.spec_bytes += ch.size
                    if ch.id in self._spec_wait_demand:
                        self._spec_wait_demand.discard(ch.id)
                        self.lifecycle_stats.spec_hit_bytes += ch.size
                    else:
                        self._spec_tier.setdefault(ch.id, 1)
                        self._spec_unhit[ch.id] = ch.size
                else:
                    self._spec_wait_demand.discard(ch.id)
            if component is not None:
                dg = component.digest()
                pend = self._comp_pending.get(dg)
                if pend is not None:
                    live = [ev for ev in pend
                            if not ev.is_set() and id(ev) not in batch]
                    if live:
                        self._comp_pending[dg] = live
                    else:
                        self._comp_pending.pop(dg, None)
                if self.path:
                    self._maybe_persist_locked(dg)
            # the batch itself is exempt from the eviction pass its own
            # commit triggers — landing bytes must not thrash themselves
            # out (mirrors the base class's exempt=dg registration rule)
            self._enforce_capacity_locked(
                exempt_chunks={ch.id for ch, _ev in claimed})
        for _ch, ev in claimed:
            ev.set()

    def reclaim_chunks(self, chunks: Sequence[Chunk]
                       ) -> List[Tuple[Chunk, threading.Event]]:
        """Re-claim awaited chunks whose original claimer aborted: any of
        ``chunks`` that is neither present nor back in flight is claimed by
        the caller (who must fetch + commit it).  The post-wait repair step
        of the fetch engine — a waiter never completes with a hole another
        build's failure left behind."""
        out: List[Tuple[Chunk, threading.Event]] = []
        with self._lock:
            for ch in chunks:
                if ch.id in self._chunk_present or \
                        ch.id in self._chunk_inflight:
                    continue
                ev = threading.Event()
                self._chunk_inflight[ch.id] = ev
                out.append((ch, ev))
                self.chunk_stats.chunks_missed += 1
        return out

    def mark_incomplete(self, c: UniformComponent) -> None:
        """Self-heal marker: the caller finished without proof that ``c``'s
        content fully landed (an awaited transfer aborted or timed out).
        The next ``plan_fetch`` of this digest re-scans and re-claims any
        missing chunks — a rescan over complete content costs one chunk
        walk and claims nothing."""
        with self._lock:
            self._incomplete.add(c.digest())

    def reclaim_component(self, c: UniformComponent
                          ) -> List[Tuple[Chunk, threading.Event]]:
        """Barrier-side repair: if ``c``'s digest was marked incomplete (the
        build transferring it aborted), re-claim its missing chunks for the
        caller to fetch.  Returns an empty list when the content is fine.
        The marker discard and the re-claims happen under one lock
        acquisition, so a concurrent plan of the same digest either sees
        the incomplete marker (and rescans itself) or sees our claims (and
        waits) — never a clean component with absent chunks."""
        dg = c.digest()
        with self._lock:
            if dg not in self._incomplete:
                return []
        chunks = self.chunks_of(c)        # hashing outside the lock
        out: List[Tuple[Chunk, threading.Event]] = []
        with self._lock:
            if dg not in self._incomplete:
                return []                 # repaired by someone else
            self._incomplete.discard(dg)
            for ch in chunks:
                if ch.id in self._chunk_present or \
                        ch.id in self._chunk_inflight:
                    continue
                ev = threading.Event()
                self._chunk_inflight[ch.id] = ev
                out.append((ch, ev))
                self.chunk_stats.chunks_missed += 1
        return out

    def abort_chunks(self,
                     claimed: Sequence[Tuple[Chunk, threading.Event]],
                     component: Optional[UniformComponent] = None
                     ) -> None:
        """Release a failed claim: chunks stay absent, waiters unblock (the
        chunk costs them nothing either way).  The component — already
        registered by ``plan_fetch`` — is marked incomplete, so the next
        build of the same digest re-plans and re-claims its missing chunks
        instead of trusting the component-level hit."""
        with self._lock:
            for ch, _ev in claimed:
                self._chunk_inflight.pop(ch.id, None)
                # a stale demand marker must not turn a future speculative
                # re-fetch of this chunk into a phantom hit
                self._spec_wait_demand.discard(ch.id)
            if component is not None:
                self._incomplete.add(component.digest())
        for _ch, ev in claimed:
            ev.set()

    def put(self, c: UniformComponent) -> bool:
        """Direct ingest (host seeding, offline suites): plan + instant
        commit, so chunk presence always tracks component presence.  A put
        racing an in-flight fetch of overlapping content does not block —
        it marks the digest incomplete instead, and the next plan of it
        re-verifies once the transfer has settled."""
        plan = self.plan_fetch(c)
        if plan.claimed:
            self.commit_chunks(plan.claimed, component=c)
        if plan.waits or plan.barriers:
            self.mark_incomplete(c)
        return plan.component_new

    # -- lifecycle: pins, eviction, GC ---------------------------------------
    def _count_refetch_locked(self, c: UniformComponent) -> None:
        # chunk granularity: the wire-accurate figure is the chunks the
        # re-registration actually claims — plan_fetch marks them via
        # _pending_refetch and commit_chunks counts them, so a plan hit on
        # a surviving shared chunk never inflates refetch_bytes
        self._pending_refetch.add(c.digest())

    def _register_refs_locked(self, dg: str, chunks: Sequence[Chunk]) -> None:
        """Record which chunks ``dg``'s content comprises, so eviction can
        mark referencing components incomplete and GC emptied ones."""
        if dg in self._comp_chunk_ids:
            return
        ids = [ch.id for ch in chunks]
        self._comp_chunk_ids[dg] = ids
        for cid in ids:
            self._chunk_refs.setdefault(cid, set()).add(dg)

    def _lease_chunk_ids(self, comps: Sequence[UniformComponent]
                         ) -> List[str]:
        # hashing happens here, outside the store lock (chunks_of memoizes)
        return [ch.id for c in comps for ch in self.chunks_of(c)]

    def _pin_chunks_locked(self, chunk_ids: Sequence[str]) -> None:
        for cid in chunk_ids:
            self._chunk_pins[cid] = self._chunk_pins.get(cid, 0) + 1

    def _unpin_chunks_locked(self, chunk_ids: Sequence[str]) -> None:
        for cid in chunk_ids:
            n = self._chunk_pins.get(cid, 0) - 1
            if n > 0:
                self._chunk_pins[cid] = n
            else:
                self._chunk_pins.pop(cid, None)

    def chunk_pinned(self, chunk_id: str) -> bool:
        with self._lock:
            return bool(self._chunk_pins.get(chunk_id))

    def _spec_chunks_locked(self, chunk_ids: Sequence[str],
                            delta: int) -> None:
        """Spec-lease refcounting of the speculative eviction tier; holds
        ``_lock``.  Decrements tolerate missing entries — a demand hit may
        have promoted the chunk out while the lease was still active."""
        if delta > 0:
            for cid in chunk_ids:
                self._spec_tier[cid] = self._spec_tier.get(cid, 0) + delta
        else:
            for cid in chunk_ids:
                n = self._spec_tier.get(cid, 0) + delta
                if n > 0:
                    self._spec_tier[cid] = n
                else:
                    self._spec_tier.pop(cid, None)

    def _promote_spec_locked(self, cid: str) -> None:
        """A real build demanded ``cid``: remove it from the speculative
        eviction tier outright (demand overrides any active spec lease) and
        drain its unhit bytes into ``spec_hit_bytes``; holds ``_lock``.
        The unhit drain is unconditional — a released spec lease drops tier
        membership but the bytes still count as a hit when demand lands."""
        self._spec_tier.pop(cid, None)
        sz = self._spec_unhit.pop(cid, None)
        if sz:
            self.lifecycle_stats.spec_hit_bytes += sz

    def chunk_speculative(self, chunk_id: str) -> bool:
        """Whether ``chunk_id`` currently sits in the speculative eviction
        tier (first victim under capacity pressure)."""
        with self._lock:
            return chunk_id in self._spec_tier

    @property
    def resident_chunk_bytes(self) -> int:
        """Bytes currently resident (evictions decrement)."""
        return self.chunk_stats.chunk_bytes_stored

    def _enforce_capacity_locked(self, exempt: Optional[str] = None,
                                 exempt_chunks: Optional[Set[str]] = None
                                 ) -> None:
        """Chunk-granularity eviction past ``capacity_bytes``; holds
        ``_lock``.  Pinned (build-lease) and in-flight-claimed chunks are
        never victims — the budget is soft against them (counted in
        ``pin_denied_evictions`` when they keep the store over budget).
        ``exempt`` (a component digest, from the base registration path) is
        irrelevant at chunk granularity: registration adds no chunk bytes.
        ``exempt_chunks`` protects a just-committed batch from the pass its
        own commit triggered."""
        if self.capacity_bytes is None:
            return
        need = self.chunk_stats.chunk_bytes_stored - self.capacity_bytes
        if need <= 0:
            return
        victims, short, pin_blocked = self._select_victims_locked(
            need, exempt_chunks)
        if short > 0 and pin_blocked:
            # only a real pin/in-flight obstruction counts as a denial — a
            # shortfall caused solely by the exempt just-committed batch is
            # a transient oversized commit, not pin pressure
            self.lifecycle_stats.pin_denied_evictions += 1
        if not victims:
            return
        # retraction BEFORE the drop: listeners (e.g. PeerIndex retraction)
        # run while the bytes are still present, so a peer that selected
        # this node either transfers before the drop or sees a store-
        # verified failure and falls back — the index never over-claims
        for listener in self.eviction_listeners:
            try:
                listener(list(victims))
            except Exception:  # noqa: BLE001 — advisory consumers only
                continue
        self._drop_chunks_locked(victims)

    def _select_victims_locked(self, need: int,
                               exempt_chunks: Optional[Set[str]] = None
                               ) -> Tuple[List[str], int, bool]:
        """Pick eviction victims worth ``need`` bytes in policy order.
        Returns (victims, bytes still unfreeable, whether a pinned or
        in-flight chunk blocked the walk).  Speculative-tier chunks
        (``spec:`` soft leases) are evicted first, LRU within the tier —
        pre-positioned bytes must never displace demand content.  Within
        the remainder, ``cheapest-to-restore`` walks peer-held chunks (LRU
        order) first — content a linked peer still holds is restored over
        a peer link, not the upstream registry — then falls back to plain
        LRU."""
        victims: List[str] = []
        pin_blocked = False
        spec_tier: List[Tuple[str, int]] = []
        candidates: List[Tuple[str, int]] = []
        for cid, size in self._chunk_present.items():
            if self._chunk_pins.get(cid) or cid in self._chunk_inflight:
                pin_blocked = True
                continue
            if exempt_chunks is not None and cid in exempt_chunks:
                continue
            if cid in self._spec_tier:
                spec_tier.append((cid, size))
            else:
                candidates.append((cid, size))
        groups = [spec_tier, candidates]
        if self.eviction_policy == "cheapest-to-restore":
            held = self._peer_held([cid for cid, _sz in candidates])
            if held is not None:
                groups = [spec_tier,
                          [cs for cs in candidates if cs[0] in held],
                          [cs for cs in candidates if cs[0] not in held]]
        for group in groups:
            for cid, size in group:
                if need <= 0:
                    break
                victims.append(cid)
                need -= size
            if need <= 0:
                break
        return victims, need, pin_blocked

    def _peer_held(self, chunk_ids: Sequence[str]) -> Optional[Set[str]]:
        """Which of ``chunk_ids`` a linked peer still holds; None without
        an oracle (policy degrades to LRU).  Prefers the batch probe — one
        peer-index snapshot per eviction pass instead of per chunk."""
        if self.peer_probe_batch is not None:
            try:
                return set(self.peer_probe_batch(chunk_ids))
            except Exception:  # noqa: BLE001 — oracle is advisory
                return set()
        if self.peer_probe is None:
            return None
        held: Set[str] = set()
        for cid in chunk_ids:
            try:
                if self.peer_probe(cid):
                    held.add(cid)
            except Exception:  # noqa: BLE001 — oracle is advisory
                continue
        return held

    def _drop_chunks_locked(self, victims: Sequence[str]) -> None:
        """Drop ``victims``' bytes, mark referencing components incomplete
        (their next plan re-scans — a miss), GC components with no content
        left; holds ``_lock``."""
        touched: Set[str] = set()
        for cid in victims:
            size = self._chunk_present.pop(cid)
            self._evicted_ids.add(cid)
            self.chunk_stats.chunks_stored -= 1
            self.chunk_stats.chunk_bytes_stored -= size
            self.chunk_stats.chunk_bytes_evicted += size
            self.lifecycle_stats.evictions += 1
            self.lifecycle_stats.evicted_bytes += size
            # speculated bytes evicted before any demand: the wager lost
            self._spec_tier.pop(cid, None)
            sz = self._spec_unhit.pop(cid, None)
            if sz:
                self.lifecycle_stats.spec_wasted_bytes += sz
            touched.update(self._chunk_refs.get(cid, ()))
        for dg in touched:
            c = self._by_digest.get(dg)
            if c is None:
                continue
            self._incomplete.add(dg)
            if self.path:
                # the persisted JSON would reload as present-with-holes;
                # pull it back until a repair re-lands the content
                self._unpersisted.setdefault(dg, c)
                try:
                    os.remove(os.path.join(self.path, dg + ".json"))
                except OSError:
                    pass
            ids = self._comp_chunk_ids.get(dg, ())
            if self._digest_pins.get(dg):
                continue
            if all(i not in self._chunk_present and
                   i not in self._chunk_inflight for i in ids):
                self._gc_component_locked(dg)

    def _gc_component_locked(self, dg: str) -> None:
        """Remove a component whose every chunk is gone: the next build of
        this digest is a plain component-level miss; holds ``_lock``."""
        c = self._by_digest.pop(dg, None)
        if c is None:
            return
        self.stats.bytes_stored -= c.size_bytes
        # refetch accounting survives GC via one digest-level marker: the
        # per-chunk markers of chunks only this component referenced are
        # dropped below (bounded bookkeeping), and a re-registration of the
        # digest re-marks exactly the chunks it re-claims (plan_fetch)
        self._evicted_digests.add(dg)
        self._incomplete.discard(dg)
        self._unpersisted.pop(dg, None)
        self._comp_pending.pop(dg, None)
        self._chunks_memo.pop(dg, None)
        for cid in self._comp_chunk_ids.pop(dg, ()):
            refs = self._chunk_refs.get(cid)
            if refs is not None:
                refs.discard(dg)
                if not refs:
                    del self._chunk_refs[cid]
                    # no component references this chunk anymore: its
                    # refetch marker is moot — drop it so a long-lived
                    # bounded node's bookkeeping stays bounded too
                    self._evicted_ids.discard(cid)
        self.lifecycle_stats.components_gcd += 1
        if self.path:
            try:
                os.remove(os.path.join(self.path, dg + ".json"))
            except OSError:
                pass
