"""Chunk-addressed component storage — the live delta-fetch layer.

``LocalComponentStore`` dedups at *component* granularity: a version bump
re-fetches the whole component even though most of its content is unchanged.
This module makes the paper's chunk-level sharing (Table 1) the live
storage/fetch path: every component is split into deterministic content
chunks (``repro.core.store.component_pieces`` — a stable fraction keyed by
``(manager, name, index)`` only, identical across versions and environment
variants), presence is tracked per chunk, and the fetch planner charges only
the chunks that are neither present nor already in flight.

Concurrency model (what ``FleetDeployer`` relies on):

  * ``plan_fetch`` atomically registers the component and *claims* its
    missing chunks under the store lock.  A claimed chunk is "in flight":
    any other build planning the same chunk — even mid-transfer — gets a
    wait handle instead of a second charge (singleflight dedup).
  * ``commit_chunks`` marks claimed chunks present and releases waiters.
  * ``abort_chunks`` releases a failed claim without marking it present, so
    one build's fetch error never wedges another build's pipeline.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .component import UniformComponent
from .store import (Chunk, LocalComponentStore, SHARED_PIECE_FRACTION,
                    component_pieces)

# Live chunk granularity.  The Table-1 *study* granularity is 64 KiB; the
# live store defaults to 4 MiB (OCI/estargz-scale blob chunking) so that
# multi-GB weight assets stay at thousands — not millions — of bookkeeping
# entries per build.
DEFAULT_CHUNK_SIZE = 4 * 2**20

# A claim is released by commit/abort in the claiming thread; the timeout is
# only a backstop against a claimer dying without either (e.g. interpreter
# teardown), so waiters degrade to a free hit instead of deadlocking.
CLAIM_WAIT_TIMEOUT_S = 60.0


@dataclasses.dataclass
class ChunkStats:
    """Chunk-level accounting on top of the component-level ``StoreStats``."""
    chunks_stored: int = 0
    chunks_hit: int = 0
    chunks_missed: int = 0
    chunks_waited: int = 0          # singleflight: in flight elsewhere
    chunk_bytes_stored: int = 0     # unique chunk bytes committed
    chunk_bytes_requested: int = 0  # new-component bytes before chunk dedup

    @property
    def delta_sharing_rate(self) -> float:
        """Fraction of new-component bytes the chunk layer did NOT transfer —
        the savings on top of component-level dedup."""
        if self.chunk_bytes_requested == 0:
            return 0.0
        return 1.0 - self.chunk_bytes_stored / self.chunk_bytes_requested

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["delta_sharing_rate"] = self.delta_sharing_rate
        return d


@dataclasses.dataclass
class FetchPlan:
    """The missing-chunk plan for one component of one build.

    ``claimed`` chunks are this build's to fetch (and charge); ``hits`` are
    already present; ``waits`` are in flight under another build's claim —
    free for this build, but not yet usable until the event fires.
    ``barriers`` are the outstanding transfer events of a component-level
    hit whose first build is still mid-flight: nothing to charge, but the
    content is not complete until they fire.  ``rescan`` marks a repair
    re-plan of a digest a previous build left incomplete — accounted as a
    miss, since it does real transfer work.
    """
    component: UniformComponent
    component_new: bool
    hits: List[Chunk]
    claimed: List[Tuple[Chunk, threading.Event]]
    waits: List[Tuple[Chunk, threading.Event]]
    barriers: List[threading.Event] = dataclasses.field(default_factory=list)
    rescan: bool = False

    @property
    def bytes_hit(self) -> int:
        return sum(ch.size for ch in self.hits) + \
            sum(ch.size for ch, _ in self.waits)

    @property
    def bytes_claimed(self) -> int:
        return sum(ch.size for ch, _ in self.claimed)


class ChunkedComponentStore(LocalComponentStore):
    """Content-addressed store with live chunk-level delta accounting.

    Component-level semantics (``put`` hit/miss, ``StoreStats``) are
    unchanged — chunk presence and singleflight claims are layered on, so a
    version-bumped component is a component-level miss whose *wire* cost is
    only its unshared chunks.
    """

    def __init__(self, path: Optional[str] = None,
                 chunk_size: int = DEFAULT_CHUNK_SIZE,
                 shared_fraction: float = SHARED_PIECE_FRACTION):
        self.chunk_size = chunk_size
        self.shared_fraction = shared_fraction
        self._chunk_present: Dict[str, int] = {}          # chunk id -> size
        self._chunk_inflight: Dict[str, threading.Event] = {}
        # component digest -> transfer events outstanding for its content,
        # so a component-level hit can still barrier on a mid-flight fetch
        self._comp_pending: Dict[str, List[threading.Event]] = {}
        # digests registered whose fetch aborted: content is incomplete and
        # the next build of the same digest must re-plan its chunks
        self._incomplete: Set[str] = set()
        # path-backed stores persist a component's JSON only once its
        # content has fully landed — a crash mid-transfer must not reload
        # as present-with-holes.  digest -> component awaiting persistence.
        self._unpersisted: Dict[str, UniformComponent] = {}
        self.chunk_stats = ChunkStats()
        super().__init__(path)
        # components reloaded from disk already hold all their chunks;
        # count them into requested too so delta_sharing_rate stays in
        # [0, 1) across restarts
        for c in self._by_digest.values():
            self.chunk_stats.chunk_bytes_requested += c.size_bytes
            for ch in self.chunks_of(c):
                if ch.id not in self._chunk_present:
                    self._chunk_present[ch.id] = ch.size
                    self.chunk_stats.chunks_stored += 1
                    self.chunk_stats.chunk_bytes_stored += ch.size

    def chunks_of(self, c: UniformComponent) -> List[Chunk]:
        return component_pieces(c, self.chunk_size, self.shared_fraction)

    def _persist(self, c: UniformComponent) -> None:
        # deferred until the transfer completes (_maybe_persist_locked)
        self._unpersisted[c.digest()] = c

    def _maybe_persist_locked(self, dg: str) -> None:
        """Flush a deferred component JSON once nothing is outstanding for
        its digest and it is not marked incomplete; callers hold _lock."""
        if dg in self._comp_pending or dg in self._incomplete:
            return
        c = self._unpersisted.pop(dg, None)
        if c is not None:
            super()._persist(c)

    def has_chunk(self, chunk_id: str) -> bool:
        with self._lock:
            return chunk_id in self._chunk_present

    def chunk_count(self) -> int:
        with self._lock:
            return len(self._chunk_present)

    def missing_chunks(self, c: UniformComponent) -> List[Chunk]:
        """Chunks of ``c`` not present locally — the proof obligation behind
        a per-component readiness signal (empty == content fully landed).
        Chunking happens outside the lock; the presence check is atomic."""
        chunks = self.chunks_of(c)
        with self._lock:
            return [ch for ch in chunks if ch.id not in self._chunk_present]

    # -- fetch protocol -------------------------------------------------------
    def plan_fetch(self, c: UniformComponent) -> FetchPlan:
        """Atomically register ``c`` and claim its missing chunks.

        For a component already stored (component-level hit) the plan
        charges nothing, but carries barrier events if the build that
        stored it is still transferring — singleflight covers same-digest
        races too.  For a new component, every chunk is classified hit /
        claim / wait under one lock acquisition, so two concurrent builds
        can never both claim (and charge) the same chunk.
        """
        dg = c.digest()
        with self._lock:
            probably_stored = dg in self._by_digest \
                and dg not in self._incomplete
        # chunking is one sha256 per chunk — a pure function of the
        # component, computed outside the lock so concurrent builds don't
        # serialize behind a multi-GB asset's hashing.  The warm path
        # (component already stored) skips it entirely.
        chunks = None if probably_stored else self.chunks_of(c)
        with self._lock:
            new = self._put_locked(c)
            hits: List[Chunk] = []
            claimed: List[Tuple[Chunk, threading.Event]] = []
            waits: List[Tuple[Chunk, threading.Event]] = []
            barriers: List[threading.Event] = []
            # an aborted earlier fetch left this digest registered but its
            # content incomplete: re-plan the chunks like a fresh miss
            rescan = not new and dg in self._incomplete
            if rescan:
                self._incomplete.discard(dg)
            if new or rescan:
                if new:
                    self.chunk_stats.chunk_bytes_requested += c.size_bytes
                if chunks is None:     # lost the probe race; rare
                    chunks = self.chunks_of(c)
                for ch in chunks:
                    if ch.id in self._chunk_present:
                        hits.append(ch)
                        self.chunk_stats.chunks_hit += 1
                    elif ch.id in self._chunk_inflight:
                        waits.append((ch, self._chunk_inflight[ch.id]))
                        self.chunk_stats.chunks_waited += 1
                    else:
                        ev = threading.Event()
                        self._chunk_inflight[ch.id] = ev
                        claimed.append((ch, ev))
                        self.chunk_stats.chunks_missed += 1
                pending = [ev for _ch, ev in claimed] + \
                    [ev for _ch, ev in waits]
                if pending:
                    self._comp_pending[dg] = pending
                elif self.path:
                    self._maybe_persist_locked(dg)   # all hits: complete now
            else:
                live = [ev for ev in self._comp_pending.get(dg, ())
                        if not ev.is_set()]
                if live:
                    self._comp_pending[dg] = live
                    barriers = live
                else:
                    self._comp_pending.pop(dg, None)
                    if self.path:
                        self._maybe_persist_locked(dg)
            return FetchPlan(component=c, component_new=new, hits=hits,
                             claimed=claimed, waits=waits, barriers=barriers,
                             rescan=rescan)

    def commit_chunks(self,
                      claimed: Sequence[Tuple[Chunk, threading.Event]],
                      component: Optional[UniformComponent] = None
                      ) -> None:
        """Mark fetched chunks present and release their waiters.  With
        ``component`` given, its pending-event record is pruned once no
        outstanding transfers remain (bounds the barrier bookkeeping)."""
        batch = {id(ev) for _ch, ev in claimed}
        with self._lock:
            for ch, _ev in claimed:
                self._chunk_present[ch.id] = ch.size
                self._chunk_inflight.pop(ch.id, None)
                self.chunk_stats.chunks_stored += 1
                self.chunk_stats.chunk_bytes_stored += ch.size
            if component is not None:
                dg = component.digest()
                pend = self._comp_pending.get(dg)
                if pend is not None:
                    live = [ev for ev in pend
                            if not ev.is_set() and id(ev) not in batch]
                    if live:
                        self._comp_pending[dg] = live
                    else:
                        self._comp_pending.pop(dg, None)
                if self.path:
                    self._maybe_persist_locked(dg)
        for _ch, ev in claimed:
            ev.set()

    def reclaim_chunks(self, chunks: Sequence[Chunk]
                       ) -> List[Tuple[Chunk, threading.Event]]:
        """Re-claim awaited chunks whose original claimer aborted: any of
        ``chunks`` that is neither present nor back in flight is claimed by
        the caller (who must fetch + commit it).  The post-wait repair step
        of the fetch engine — a waiter never completes with a hole another
        build's failure left behind."""
        out: List[Tuple[Chunk, threading.Event]] = []
        with self._lock:
            for ch in chunks:
                if ch.id in self._chunk_present or \
                        ch.id in self._chunk_inflight:
                    continue
                ev = threading.Event()
                self._chunk_inflight[ch.id] = ev
                out.append((ch, ev))
                self.chunk_stats.chunks_missed += 1
        return out

    def mark_incomplete(self, c: UniformComponent) -> None:
        """Self-heal marker: the caller finished without proof that ``c``'s
        content fully landed (an awaited transfer aborted or timed out).
        The next ``plan_fetch`` of this digest re-scans and re-claims any
        missing chunks — a rescan over complete content costs one chunk
        walk and claims nothing."""
        with self._lock:
            self._incomplete.add(c.digest())

    def reclaim_component(self, c: UniformComponent
                          ) -> List[Tuple[Chunk, threading.Event]]:
        """Barrier-side repair: if ``c``'s digest was marked incomplete (the
        build transferring it aborted), re-claim its missing chunks for the
        caller to fetch.  Returns an empty list when the content is fine.
        The marker discard and the re-claims happen under one lock
        acquisition, so a concurrent plan of the same digest either sees
        the incomplete marker (and rescans itself) or sees our claims (and
        waits) — never a clean component with absent chunks."""
        dg = c.digest()
        with self._lock:
            if dg not in self._incomplete:
                return []
        chunks = self.chunks_of(c)        # hashing outside the lock
        out: List[Tuple[Chunk, threading.Event]] = []
        with self._lock:
            if dg not in self._incomplete:
                return []                 # repaired by someone else
            self._incomplete.discard(dg)
            for ch in chunks:
                if ch.id in self._chunk_present or \
                        ch.id in self._chunk_inflight:
                    continue
                ev = threading.Event()
                self._chunk_inflight[ch.id] = ev
                out.append((ch, ev))
                self.chunk_stats.chunks_missed += 1
        return out

    def abort_chunks(self,
                     claimed: Sequence[Tuple[Chunk, threading.Event]],
                     component: Optional[UniformComponent] = None
                     ) -> None:
        """Release a failed claim: chunks stay absent, waiters unblock (the
        chunk costs them nothing either way).  The component — already
        registered by ``plan_fetch`` — is marked incomplete, so the next
        build of the same digest re-plans and re-claims its missing chunks
        instead of trusting the component-level hit."""
        with self._lock:
            for ch, _ev in claimed:
                self._chunk_inflight.pop(ch.id, None)
            if component is not None:
                self._incomplete.add(component.digest())
        for _ch, ev in claimed:
            ev.set()

    def put(self, c: UniformComponent) -> bool:
        """Direct ingest (host seeding, offline suites): plan + instant
        commit, so chunk presence always tracks component presence.  A put
        racing an in-flight fetch of overlapping content does not block —
        it marks the digest incomplete instead, and the next plan of it
        re-verifies once the transfer has settled."""
        plan = self.plan_fetch(c)
        if plan.claimed:
            self.commit_chunks(plan.claimed, component=c)
        if plan.waits or plan.barriers:
            self.mark_incomplete(c)
        return plan.component_new
