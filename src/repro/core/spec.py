"""SpecSheet: the deployment platform description the lazy-builder reads.

The paper's specSheet "encapsulates the local hardware and software
configurations" (CPU arch, system type, interpreter, libc).  Our deployment
platforms are JAX meshes on concrete chips, so the specSheet carries the
mesh topology, per-chip compute/memory/interconnect numbers and the software
facts (jax version, backend, dtype support) that environment selection
(Algorithm 1's ES) matches component requirements against.
"""
from __future__ import annotations

import dataclasses
import json
import platform as _platform
from typing import Any, Dict, Mapping, Optional, Tuple

# ---------------------------------------------------------------------------
# Chip descriptions (hardware constants used for deployability + roofline).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    vendor: str
    peak_flops_bf16: float          # FLOP/s per chip
    hbm_bytes: int                  # bytes per chip
    hbm_bw: float                   # bytes/s per chip
    vmem_bytes: int                 # on-chip scratch (VMEM / L2)
    ici_bw_per_link: float          # bytes/s per ICI link
    ici_links: int                  # links per chip (torus degree)
    dci_bw: float                   # inter-pod bytes/s per chip (data-center net)
    mxu_align: int = 128            # matmul tile alignment
    supports: Tuple[str, ...] = ("bf16", "f32")


TPU_V5E = ChipSpec(
    name="tpu-v5e", vendor="google",
    peak_flops_bf16=197e12, hbm_bytes=16 * 2**30, hbm_bw=819e9,
    vmem_bytes=128 * 2**20, ici_bw_per_link=50e9, ici_links=4,
    dci_bw=25e9 / 8 * 4,  # ~4x 25Gbps NICs per host, bytes/s per chip (approx)
    supports=("bf16", "f32", "int8", "f8"),
)

CPU_HOST = ChipSpec(
    name="cpu-host", vendor="generic",
    peak_flops_bf16=100e9, hbm_bytes=32 * 2**30, hbm_bw=20e9,
    vmem_bytes=32 * 2**20, ici_bw_per_link=10e9, ici_links=1, dci_bw=1e9,
    supports=("f32", "bf16"),
)

# A GPU-flavoured platform: exercises the paper's cross-platform claim with a
# third heterogeneous target (deployability must pick different variants).
GPU_A100 = ChipSpec(
    name="gpu-a100", vendor="nvidia",
    peak_flops_bf16=312e12, hbm_bytes=80 * 2**30, hbm_bw=2039e9,
    vmem_bytes=40 * 2**20, ici_bw_per_link=300e9, ici_links=1, dci_bw=25e9 / 8,
    supports=("bf16", "f32", "f16", "int8"),
)

CHIPS = {c.name: c for c in (TPU_V5E, CPU_HOST, GPU_A100)}


# ---------------------------------------------------------------------------
# SpecSheet
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpecSheet:
    """Everything the lazy-builder knows about the deployment platform."""

    platform_id: str                      # human name ("tpu-v5e-pod0")
    chip: ChipSpec
    mesh_shape: Tuple[int, ...]           # e.g. (16, 16) or (2, 16, 16)
    mesh_axes: Tuple[str, ...]            # e.g. ("data", "model")
    num_hosts: int = 1
    backend: str = "cpu"                  # jax backend actually present
    interpret_kernels: bool = True        # pallas must run interpret on CPU
    jax_version: str = ""
    os: str = ""
    cpu_arch: str = ""
    python: str = ""
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- derived ------------------------------------------------------------
    @property
    def num_chips(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n

    @property
    def axis_size(self) -> Dict[str, int]:
        return dict(zip(self.mesh_axes, self.mesh_shape))

    def axis(self, name: str, default: int = 1) -> int:
        return self.axis_size.get(name, default)

    @property
    def total_hbm(self) -> int:
        return self.num_chips * self.chip.hbm_bytes

    # The "building context" seed (Algorithm 2 initializes C from the host).
    def context(self) -> Dict[str, Any]:
        return {
            "chip": self.chip.name,
            "vendor": self.chip.vendor,
            "backend": self.backend,
            "mesh.shape": list(self.mesh_shape),
            "mesh.axes": list(self.mesh_axes),
            "mesh.chips": self.num_chips,
            "mesh.data": self.axis("data"),
            "mesh.model": self.axis("model"),
            "mesh.pod": self.axis("pod"),
            "interpret": self.interpret_kernels,
            "dtypes": list(self.chip.supports),
            "hbm.per_chip": self.chip.hbm_bytes,
            "vmem": self.chip.vmem_bytes,
        }

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, sort_keys=True)

    def digest(self) -> str:
        """Stable content digest of the platform description (cache key)."""
        import hashlib
        return hashlib.sha256(self.to_json().encode()).hexdigest()

    @staticmethod
    def from_json(s: str) -> "SpecSheet":
        d = json.loads(s)
        d["chip"] = ChipSpec(**d["chip"])
        d["mesh_shape"] = tuple(d["mesh_shape"])
        d["mesh_axes"] = tuple(d["mesh_axes"])
        d["chip"] = dataclasses.replace(d["chip"], supports=tuple(d["chip"].supports))
        return SpecSheet(**d)


def probe_host(platform_id: str = "local",
               mesh_shape: Tuple[int, ...] = (1,),
               mesh_axes: Tuple[str, ...] = ("data",),
               chip: Optional[ChipSpec] = None) -> SpecSheet:
    """Inspect the *actual* host (paper: 'inspects the target hardware and
    driver configuration').  Used for smoke tests and CPU execution."""
    import jax  # local import: keep module import free of jax side effects

    backend = jax.default_backend()
    if chip is None:
        if backend == "tpu":
            chip = TPU_V5E
        elif backend in ("gpu", "cuda", "rocm"):
            chip = GPU_A100
        else:
            chip = CPU_HOST
    return SpecSheet(
        platform_id=platform_id,
        chip=chip,
        mesh_shape=mesh_shape,
        mesh_axes=mesh_axes,
        backend=backend,
        interpret_kernels=(backend != "tpu"),
        jax_version=jax.__version__,
        os=_platform.system().lower(),
        cpu_arch=_platform.machine(),
        python=_platform.python_version(),
    )


# -- canonical deployment platforms used across benchmarks/dry-runs ---------

def tpu_single_pod(data: int = 16, model: int = 16) -> SpecSheet:
    return SpecSheet(
        platform_id=f"tpu-v5e-{data}x{model}",
        chip=TPU_V5E, mesh_shape=(data, model), mesh_axes=("data", "model"),
        num_hosts=data * model // 4, backend="cpu", interpret_kernels=True,
    )


def tpu_multi_pod(pods: int = 2, data: int = 16, model: int = 16) -> SpecSheet:
    return SpecSheet(
        platform_id=f"tpu-v5e-{pods}x{data}x{model}",
        chip=TPU_V5E, mesh_shape=(pods, data, model),
        mesh_axes=("pod", "data", "model"),
        num_hosts=pods * data * model // 4, backend="cpu",
        interpret_kernels=True,
    )


def cpu_smoke(devices: int = 1) -> SpecSheet:
    return SpecSheet(
        platform_id=f"cpu-smoke-{devices}",
        chip=CPU_HOST, mesh_shape=(devices,), mesh_axes=("data",),
        backend="cpu", interpret_kernels=True,
    )


def gpu_server() -> SpecSheet:
    """The paper's 'GPU Server' platform flavour (A100) — used to show the
    same CIR resolving to different variants on a heterogeneous target."""
    return SpecSheet(
        platform_id="gpu-a100-8", chip=GPU_A100, mesh_shape=(8,),
        mesh_axes=("data",), backend="cpu", interpret_kernels=True,
    )


PLATFORM_PRESETS = {
    "cpu-smoke": cpu_smoke,
    "tpu-pod": tpu_single_pod,
    "tpu-multipod": tpu_multi_pod,
    "gpu-server": gpu_server,
}
