"""Uniform Component Registry + upstream sources + converters (paper §4.3).

The registry answers the three queries of Algorithm 1:

    VQ : (M, n)       -> V      (available versions)
    EQ : (M, n, v)    -> E      (environment variants of a version)
    CQ : (M, n, v, e) -> c      (the component itself)

Upstream sources model PyPI / Debian-snapshot / DockerHub: in this framework
they are generators that *convert* raw catalog entries (python module
factories, generated weight assets, HF-style config dicts) into uniform
components on demand — the paper's component converters.
"""
from __future__ import annotations

import json
import os
import threading
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Set, Tuple)

from .component import (DependencyItem, Requirement, UniformComponent,
                        Version, component_sort_key)


class RegistryError(KeyError):
    pass


class UniformComponentRegistry:
    """In-memory + optional on-disk index of uniform components.

    The registry carries a **catalog epoch** — a content fingerprint that
    changes every time the catalog's *content* actually changes (a new
    component, or an overwrite with different bytes).  It is derived from
    the component digests themselves (an order-independent XOR fold), so it
    is identical across processes and restarts for identical catalog
    content: persistent caches keyed by it (the build-plan cache) stay warm
    across restarts and invalidate exactly when content changes.  Identical
    re-registration — the common case when upstream converters re-run —
    leaves it untouched.
    """

    def __init__(self, path: Optional[str] = None):
        self._by_mn: Dict[Tuple[str, str], Dict[str, Dict[str, UniformComponent]]] = {}
        self._lock = threading.Lock()
        self._fingerprint = 0
        self.path = path
        if path and os.path.exists(path):
            self.load(path)

    @property
    def epoch(self) -> str:
        """Content fingerprint of the catalog (hex, restart-stable)."""
        return format(self._fingerprint, "016x")

    @staticmethod
    def _fold(c: UniformComponent) -> int:
        return int(c.digest()[:16], 16)

    # -- registration --------------------------------------------------------
    def register(self, c: UniformComponent, overwrite: bool = False) -> None:
        with self._lock:
            vs = self._by_mn.setdefault((c.manager, c.name), {})
            es = vs.setdefault(c.version, {})
            if c.env in es:
                if es[c.env].digest() == c.digest():
                    return  # identical re-registration: no content change
                if not overwrite:
                    # components are immutable: re-registration must be identical
                    raise RegistryError(
                        f"immutable component re-registered with different "
                        f"content: {c.ident_str()}")
                self._fingerprint ^= self._fold(es[c.env])   # retire old
            es[c.env] = c
            self._fingerprint ^= self._fold(c)

    def register_all(self, comps: Iterable[UniformComponent]) -> None:
        for c in comps:
            self.register(c)

    # -- the three queries ----------------------------------------------------
    # reads snapshot under the lock: upstream pulls register components
    # concurrently with sibling fleet builds' resolutions
    def vq(self, manager: str, name: str) -> List[str]:
        with self._lock:
            keys = list(self._by_mn.get((manager, name), {}).keys())
        return sorted(keys, key=Version.parse)

    def eq(self, manager: str, name: str, version: str) -> List[str]:
        with self._lock:
            vs = self._by_mn.get((manager, name), {})
            return sorted(vs.get(version, {}).keys())

    def cq(self, manager: str, name: str, version: str, env: str
           ) -> UniformComponent:
        with self._lock:
            try:
                return self._by_mn[(manager, name)][version][env]
            except KeyError:
                pass
        raise RegistryError(
            f"no component {manager}:{name}=={version}@{env}")

    # -- bulk views ------------------------------------------------------------
    def candidates(self, manager: str, name: str, version: str
                   ) -> List[UniformComponent]:
        with self._lock:
            vs = self._by_mn.get((manager, name), {})
            cands = list(vs.get(version, {}).values())
        return sorted(cands, key=component_sort_key)

    def all_components(self) -> List[UniformComponent]:
        out: List[UniformComponent] = []
        with self._lock:
            for vs in self._by_mn.values():
                for es in vs.values():
                    out.extend(es.values())
        return out

    def names(self, manager: Optional[str] = None) -> List[Tuple[str, str]]:
        with self._lock:
            keys = list(self._by_mn.keys())
        if manager is not None:
            keys = [k for k in keys if k[0] == manager]
        return sorted(keys)

    def __len__(self) -> int:
        with self._lock:
            return sum(len(es) for vs in self._by_mn.values()
                       for es in vs.values())

    # -- persistence ------------------------------------------------------------
    def dump(self, path: Optional[str] = None) -> None:
        path = path or self.path
        assert path, "no registry path"
        data = [c.to_json() for c in self.all_components()]
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f)
        os.replace(tmp, path)

    def load(self, path: str) -> None:
        with open(path) as f:
            data = json.load(f)
        for d in data:
            self.register(UniformComponent.from_json(d), overwrite=True)


# ---------------------------------------------------------------------------
# Upstream sources + converters
# ---------------------------------------------------------------------------

class UpstreamSource:
    """Models one upstream ecosystem (PyPI / Debian / DockerHub analogue).

    ``lister``  : () -> iterable of raw entries
    ``converter``: raw entry -> [UniformComponent]  (the paper's converter)

    The full ``lister()`` + ``converter()`` sweep is the expensive part of a
    registry miss, so its output is indexed per ``(manager, name)`` on first
    use: later lookups — including negative ones (a name this source simply
    does not carry) — are answered from the index without re-scanning.
    ``invalidate()`` drops the index when upstream content changes.
    """

    def __init__(self, name: str,
                 lister: Callable[[], Iterable],
                 converter: Callable[[object], Sequence[UniformComponent]]):
        self.name = name
        self.lister = lister
        self.converter = converter
        self._index: Optional[Dict[Tuple[str, str],
                                   List[UniformComponent]]] = None
        self._lock = threading.Lock()
        self.scans = 0              # full lister+converter sweeps performed
        self.index_hits = 0         # lookups answered without a sweep

    def _indexed(self) -> Dict[Tuple[str, str], List[UniformComponent]]:
        """Build (once) the per-(M, n) converted index; callers hold _lock."""
        if self._index is None:
            self.scans += 1
            idx: Dict[Tuple[str, str], List[UniformComponent]] = {}
            for raw in self.lister():
                for c in self.converter(raw):
                    idx.setdefault((c.manager, c.name), []).append(c)
            self._index = idx
        else:
            self.index_hits += 1
        return self._index

    def invalidate(self) -> None:
        with self._lock:
            self._index = None

    def convert_all(self) -> List[UniformComponent]:
        with self._lock:
            idx = self._indexed()
            return [c for comps in idx.values() for c in comps]

    def convert_matching(self, manager: str, name: str
                         ) -> List[UniformComponent]:
        with self._lock:
            return list(self._indexed().get((manager, name), ()))


class UniformComponentService:
    """Registry-first, upstream-fallback component service (paper Fig. 5).

    Network usage is *byte-accounted*: every component handed to a client is
    charged its ``size_bytes`` so benchmarks can model links from 10 Mbps to
    1 Gbps without real networking.
    """

    def __init__(self, registry: UniformComponentRegistry,
                 upstreams: Sequence[UpstreamSource] = ()):
        self.registry = registry
        self.upstreams = list(upstreams)
        self.bytes_served = 0
        self.requests = 0
        self.chunk_requests = 0
        self.conversions = 0
        # repeated registry misses for the same unknown (M, n) are answered
        # from this negative cache instead of re-consulting every upstream
        self._upstream_negative: Set[Tuple[str, str]] = set()
        self._upstream_lock = threading.Lock()
        self.upstream_rescans_avoided = 0   # lookups served from an index
        self.upstream_negative_hits = 0     # pulls skipped via negative cache

    @property
    def catalog_epoch(self) -> str:
        """Content epoch of the backing registry (see registry docstring)."""
        return self.registry.epoch

    # -- queries with on-demand conversion -----------------------------------
    def vq(self, manager: str, name: str) -> List[str]:
        vs = self.registry.vq(manager, name)
        if not vs:
            self._pull_upstream(manager, name)
            vs = self.registry.vq(manager, name)
        return vs

    def eq(self, manager: str, name: str, version: str) -> List[str]:
        es = self.registry.eq(manager, name, version)
        if not es:
            self._pull_upstream(manager, name)
            es = self.registry.eq(manager, name, version)
        return es

    def cq(self, manager: str, name: str, version: str, env: str
           ) -> UniformComponent:
        try:
            return self.registry.cq(manager, name, version, env)
        except RegistryError:
            # paper Fig. 5: registry miss → fetch + convert from upstream
            self._pull_upstream(manager, name)
            return self.registry.cq(manager, name, version, env)

    def candidates(self, manager: str, name: str, version: str
                   ) -> List[UniformComponent]:
        return self.registry.candidates(manager, name, version)

    def fetch(self, c: UniformComponent) -> UniformComponent:
        """'Download' a whole component: account its bytes."""
        self.requests += 1
        self.bytes_served += c.size_bytes
        return c

    def fetch_chunks(self, c: UniformComponent, nbytes: int,
                     nchunks: int = 1) -> UniformComponent:
        """'Download' a chunk range of a component: account delta bytes only
        (the chunk-addressed fetch path — paper Table 1 made live)."""
        self.requests += 1
        self.chunk_requests += nchunks
        self.bytes_served += nbytes
        return c

    def invalidate_upstreams(self) -> None:
        """Upstream content changed: drop every source's converted index AND
        this service's negative cache, so names that newly appeared upstream
        become resolvable again."""
        with self._upstream_lock:
            for up in self.upstreams:
                up.invalidate()
            self._upstream_negative.clear()

    def _pull_upstream(self, manager: str, name: str) -> None:
        # the service lock guards only the negative cache + counters; the
        # sweep itself is singleflighted per source (UpstreamSource._lock),
        # so misses for unrelated names don't serialize behind each other
        key = (manager, name)
        with self._upstream_lock:
            if key in self._upstream_negative:
                self.upstream_negative_hits += 1
                return
        for up in self.upstreams:
            scans_before = up.scans
            converted = up.convert_matching(manager, name)
            with self._upstream_lock:
                if up.scans == scans_before:
                    self.upstream_rescans_avoided += 1
                if converted:
                    self.conversions += len(converted)
            if converted:
                self.registry.register_all(converted)
                return
        with self._upstream_lock:
            self._upstream_negative.add(key)
