"""Fleet-wide compile cache: compiled executables as CIR components.

The lazy-build pipeline defers platform-specific construction to deploy
time, but until now the XLA compile stage was the one stage that
content-addressing never amortized: every cold node paid it from scratch.
This module makes the compiled executable a first-class, content-addressed
component class:

* :func:`compile_cache_key` derives a fleet-stable key from the staged
  program (the assemble-gated component pins of the lockfile — the
  HLO/StableHLO identity), the platform *class* (chip, mesh, backend — NOT
  the per-node ``platform_id``), and the jax/XLA version plus a format
  salt.  Two nodes of the same platform class deploying the same lock
  derive the same key, so one node's compile is every peer's cache hit.
* :func:`artifact_component` wraps a key in a ``UniformComponent`` under
  the ``compiled`` manager.  Because the key (not the node) is the
  identity, the component digest — and therefore its chunk ids — are
  identical fleet-wide, and the executable rides the existing
  PeerIndex/NodePeering chunk path with the same singleflight, pin-lease
  and eviction rules as every other component.
* :class:`CompileCache` is the control-plane index (an LRU mirror of
  ``BuildPlanCache``): key -> :class:`CompiledArtifact`.  The *bytes* live
  in the per-node ``ChunkedComponentStore``; the cache only remembers that
  a compatible executable exists and which component carries it.

Compiled artifacts are born on fleet nodes — the upstream registry never
stores them — so a cache hit whose bytes are locally absent is sourced
from peers only; if no linked peer still holds the chunks, the node
recompiles (and re-publishes).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

from .component import UniformComponent
from .irmodule import (PROGRAM_MANAGERS, TAIL_BYTES_BASE,  # noqa: F401
                       TAIL_BYTES_PER_ENTRY, ir_module_digest,
                       partition_plan_digest)

# Manager namespace for compiled executables.  Never resolved from a CIR
# dependency closure — artifact components are created by the compile
# stage and distributed peer-to-peer.
COMPILED_MANAGER = "compiled"

# Version salt folded into every cache key: bump when the artifact format
# or the key derivation changes so stale executables can never false-hit.
# v2: the program identity is the real IR module digest (doc §13), no
# longer the lock-digest proxy — v1 keys must never alias v2 entries.
COMPILE_VERSION_SALT = "cir-xla-exec-v2"
LEGACY_COMPILE_VERSION_SALT = "cir-xla-exec-v1"

# Deterministic cost/size model for the executable.  Real XLA compiles of
# multi-billion-parameter programs take tens of seconds; the discrete-event
# clock observes this per staged entrypoint on a cache miss (wall-clock
# transports measure the real jit wall instead).
COMPILE_VIRTUAL_S_PER_ENTRY = 8.0
ARTIFACT_BYTES_BASE = 24 * 2 ** 20         # serialized executable envelope
ARTIFACT_BYTES_PER_ENTRY = 8 * 2 ** 20     # per staged step function


def compile_cache_key(lock, spec, entry_names: Sequence[str]) -> str:
    """Derive the fleet-wide cache key for a compiled executable.

    Digest inputs (doc §10, §13): the *program* — the real IR module
    digest (:func:`repro.core.irmodule.ir_module_digest`, derived from
    the lock closure, so semantically identical programs resolved from
    different catalogs share compiled artifacts); the *platform class* —
    chip, mesh shape/axes, backend, kernel-interpret mode and the
    platform-selected partition plan, deliberately excluding
    ``platform_id`` so same-class nodes share; and the *version salt* —
    the spec's jax version plus :data:`COMPILE_VERSION_SALT`.
    """
    blob = json.dumps({
        "ir_module": ir_module_digest(lock, entry_names),
        "platform": {
            "chip": spec.chip.name,
            "mesh_shape": list(spec.mesh_shape),
            "mesh_axes": list(spec.mesh_axes),
            "backend": spec.backend,
            "interpret_kernels": spec.interpret_kernels,
            "partition_plan": partition_plan_digest(lock),
        },
        "version": {"jax": spec.jax_version,
                    "salt": COMPILE_VERSION_SALT},
    }, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def legacy_compile_cache_key(lock, spec,
                             entry_names: Sequence[str]) -> str:
    """The pre-§13 (v1) key derivation: the lock-digest *proxy* for the
    program identity.  Kept only as a compat shim so callers holding old
    keys can recognise them — new cache entries are keyed exclusively by
    :func:`compile_cache_key`, and the salt split guarantees a v1 key can
    never alias (or leak into) a v2 entry."""
    program = sorted(
        d for (m, _n, _v, _e), d in zip(lock.pins, lock.digests)
        if m in PROGRAM_MANAGERS)
    blob = json.dumps({
        "program": program,
        "entries": sorted(entry_names),
        "platform": {
            "chip": spec.chip.name,
            "mesh_shape": list(spec.mesh_shape),
            "mesh_axes": list(spec.mesh_axes),
            "backend": spec.backend,
            "interpret_kernels": spec.interpret_kernels,
        },
        "version": {"jax": spec.jax_version,
                    "salt": LEGACY_COMPILE_VERSION_SALT},
    }, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def artifact_component(key: str, entry_names: Sequence[str],
                       tail: bool = False) -> UniformComponent:
    """The content-addressed carrier for one compiled executable.

    The key is the whole identity: every node of the platform class
    constructs a byte-identical component (and therefore identical chunk
    ids), which is what lets the executable flow over the ordinary
    peer-to-peer chunk path.  With ``tail=True`` the carrier holds only
    the per-platform remainder of the split executable (doc §13) — the
    platform-neutral majority lives in the shared ``manager="ir"``
    module — sized so IR + tail equals the monolithic envelope.
    """
    names = tuple(sorted(entry_names))
    if tail:
        size = TAIL_BYTES_BASE + TAIL_BYTES_PER_ENTRY * len(names)
        name = f"xla-tail-{key[:16]}"
    else:
        size = ARTIFACT_BYTES_BASE + ARTIFACT_BYTES_PER_ENTRY * len(names)
        name = f"xla-exec-{key[:16]}"
    return UniformComponent(
        manager=COMPILED_MANAGER,
        name=name,
        version="1.0",
        env="any",
        context={"compile_key": key, "entries": list(names), "tail": tail},
        payload="",
        size_bytes=size,
    )


@dataclasses.dataclass(frozen=True)
class CompiledArtifact:
    """One cached executable: the key, its carrier component, and what the
    original compile cost (virtual seconds) so reports can say what a hit
    saved.  Under the §13 split the carrier is the platform tail and
    ``autotune`` names the Pallas autotune-table component that rides
    with it (``None`` for monolithic v1-style artifacts)."""
    key: str
    component: UniformComponent
    entry_names: Tuple[str, ...]
    compile_s: float = 0.0
    autotune: Optional[UniformComponent] = None


@dataclasses.dataclass
class CompileCacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    compile_skips: int = 0        # step compiles avoided via hits
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CompileCache:
    """Thread-safe LRU index of compiled executables (control plane only).

    Shared across all node builders of a fleet — like the build-plan
    cache, it is deployment-service metadata, not node storage.  The
    executable *bytes* live in per-node chunk stores and obey those
    stores' capacity/eviction/pin rules; an entry here only asserts that
    an executable with this key exists somewhere and names the component
    that carries it.
    """

    def __init__(self, max_entries: Optional[int] = None) -> None:
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.stats = CompileCacheStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, CompiledArtifact]" = OrderedDict()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: str) -> Optional[CompiledArtifact]:
        with self._lock:
            art = self._entries.get(key)
            if art is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return art

    def put(self, art: CompiledArtifact) -> None:
        with self._lock:
            self._entries[art.key] = art
            self._entries.move_to_end(art.key)
            self.stats.puts += 1
            while (self.max_entries is not None
                   and len(self._entries) > self.max_entries):
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    def drop(self, key: str) -> bool:
        with self._lock:
            return self._entries.pop(key, None) is not None

    def artifacts(self) -> Dict[str, CompiledArtifact]:
        with self._lock:
            return dict(self._entries)
