"""Algorithm 2 — Uniform Dependency Resolution.

BFS over the dependency tree, with a *building context* ``C`` flowing across
managers (the paper's cross-manager compatibility mechanism), and
conflict-driven constraint learning with deterministic restarts (a compact
CDCL in the style the paper cites [14]).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .component import DependencyItem, Specifier, UniformComponent, Version
from .registry import UniformComponentService
from .selection import (DeployabilityEvaluator, SelectionError,
                        uniform_component_selection)


class ResolutionError(Exception):
    pass


@dataclasses.dataclass
class Node:
    dep: DependencyItem
    component: Optional[UniformComponent] = None
    children: List["Node"] = dataclasses.field(default_factory=list)
    reused: bool = False

    def walk(self):
        yield self
        for ch in self.children:
            yield from ch.walk()


# Manager-specific "getSpec(C)" hooks: derive extra version constraints from
# the building context (e.g. interpreter version for pip in the paper; dtype
# or mesh divisibility facts here).  Registered by the catalog.
_CONTEXT_SPEC_HOOKS: Dict[str, Callable[[str, Mapping[str, Any]], Optional[str]]] = {}


def register_context_spec_hook(
        manager: str,
        hook: Callable[[str, Mapping[str, Any]], Optional[str]]) -> None:
    _CONTEXT_SPEC_HOOKS[manager] = hook


def _get_spec(dep: DependencyItem, ctx: Mapping[str, Any]) -> Optional[str]:
    hook = _CONTEXT_SPEC_HOOKS.get(dep.manager)
    return hook(dep.name, ctx) if hook else None


@dataclasses.dataclass
class Resolution:
    components: List[UniformComponent]       # L, BFS order, deduped
    context: Dict[str, Any]                  # final building context
    tree: Node
    restarts: int
    learned: Dict[Tuple[str, str], str]      # learned version constraints
    selected_by_key: Dict[Tuple[str, str], UniformComponent] = \
        dataclasses.field(default_factory=dict)

    def pins(self) -> Tuple[Tuple[str, str, str, str], ...]:
        """The version-lock pins (M, n, v, e) in selection order."""
        return tuple(c.ident() for c in self.components)

    def pin_digests(self) -> Tuple[str, ...]:
        return tuple(c.digest() for c in self.components)

    def component_records(self) -> List[Dict[str, Any]]:
        """One plain-dict record per resolved component, canonically sorted
        by (manager, name, version, env) — the SBOM's source of truth for
        the dependency closure (docs §12)."""
        recs = [{
            "manager": c.manager, "name": c.name, "version": c.version,
            "env": c.env, "digest": c.digest(), "size_bytes": c.size_bytes,
        } for c in self.components]
        recs.sort(key=lambda r: (r["manager"], r["name"], r["version"],
                                 r["env"]))
        return recs

    def explain(self) -> str:
        lines: List[str] = []

        def rec(n: Node, depth: int):
            tag = ""
            if n.reused:
                tag = "  (reused)"
            cid = n.component.ident_str() if n.component else "<unresolved>"
            lines.append("  " * depth + f"{n.dep} -> {cid}{tag}")
            for ch in n.children:
                rec(ch, depth + 1)

        for ch in self.tree.children:
            rec(ch, 0)
        return "\n".join(lines)


def resolution_from_pins(
        pins: Sequence[Tuple[str, str, str, str]],
        service: UniformComponentService,
        host_context: Mapping[str, Any],
        expected_digests: Optional[Sequence[str]] = None,
) -> Resolution:
    """Replay a version-lock manifest: CQ-only (no VS/ES), deterministic.

    Reconstructs the full ``Resolution`` — including the final building
    context, by merging each pinned component's context contribution in the
    original selection order — without running Algorithm 2.  This is the
    fast path shared by CIR-locked rebuilds and the build-plan cache.
    ``expected_digests`` enforces component immutability when given.
    """
    comps = [service.cq(*pin) for pin in pins]
    if expected_digests is not None:
        if len(expected_digests) != len(comps):
            raise ResolutionError(
                f"lock has {len(comps)} pins but "
                f"{len(expected_digests)} digests — refusing to replay "
                f"with partial immutability verification")
        for c, dg in zip(comps, expected_digests):
            if c.digest() != dg:
                raise ResolutionError(
                    f"immutability violation for {c.ident_str()}")
    ctx: Dict[str, Any] = dict(host_context)
    for c in comps:
        ctx.update(c.context)
    return Resolution(
        components=comps, context=ctx, tree=Node(
            DependencyItem("root", "root", "any")),
        restarts=0, learned={},
        selected_by_key={(c.manager, c.name): c for c in comps})


def uniform_dependency_resolution(
        deps: Sequence[DependencyItem],
        service: UniformComponentService,
        host_context: Mapping[str, Any],
        cached_digests: Optional[set] = None,
        link_bandwidth: float = 500e6 / 8,
        max_restarts: int = 32,
        max_nodes: int = 4096,
) -> Resolution:
    """The paper's Algorithm 2 with restart-based conflict learning.

    A *conflict* arises when a newly selected component requires (M, n) at a
    version incompatible with the component already chosen for (M, n).  We
    learn the conjunction of every specifier seen for (M, n) and restart;
    selection under the learned constraint either converges or proves
    unsatisfiability (SelectionError -> ResolutionError).
    """
    learned: Dict[Tuple[str, str], str] = {}
    restarts = 0

    while True:
        try:
            return _resolve_once(deps, service, host_context, learned,
                                 cached_digests, link_bandwidth, restarts,
                                 max_nodes)
        except _Conflict as cf:
            restarts += 1
            if restarts > max_restarts:
                raise ResolutionError(
                    f"conflict resolution did not converge after "
                    f"{max_restarts} restarts: {cf}") from None
            key = cf.key
            merged = Specifier(learned.get(key, "any"))
            for s in cf.specs:
                merged = Specifier(merged.intersect_text(Specifier(s)))
            learned[key] = merged.text
        except SelectionError as e:
            raise ResolutionError(str(e)) from e


class _Conflict(Exception):
    def __init__(self, key: Tuple[str, str], specs: Sequence[str]):
        super().__init__(f"{key[0]}:{key[1]} constrained by {list(specs)}")
        self.key = key
        self.specs = list(specs)


def _resolve_once(deps, service, host_context, learned, cached_digests,
                  link_bandwidth, restart_idx, max_nodes) -> Resolution:
    ctx: Dict[str, Any] = dict(host_context)
    root = Node(DependencyItem("root", "root", "any"))
    for d in deps:
        root.children.append(Node(d))

    selected: Dict[Tuple[str, str], UniformComponent] = {}
    seen_specs: Dict[Tuple[str, str], List[str]] = {}
    order: List[UniformComponent] = []

    queue: deque[Node] = deque(root.children)
    visited = 0
    while queue:
        node = queue.popleft()
        visited += 1
        if visited > max_nodes:
            raise ResolutionError(f"dependency tree exceeded {max_nodes} nodes")
        d = node.dep
        key = d.key()
        seen_specs.setdefault(key, []).append(d.specifier)

        # node.d.SatisfiedBy(L): reuse if the already-selected component for
        # this (M, n) matches this node's specifier.
        if key in selected:
            prev = selected[key]
            if d.spec.matches(Version.parse(prev.version)):
                node.component = prev
                node.reused = True
                continue
            # incompatible requirement on an already-pinned component
            raise _Conflict(key, seen_specs[key])

        extra = learned.get(key)
        ctx_spec = _get_spec(d, ctx)
        if ctx_spec:
            extra = (Specifier(extra).intersect_text(Specifier(ctx_spec))
                     if extra else ctx_spec)

        evaluator = DeployabilityEvaluator(ctx, cached_digests, link_bandwidth)
        cs = uniform_component_selection(d, service, evaluator,
                                         extra_constraint=extra)

        # hasConflict(): the fresh selection may clash with learned constraints
        # raised by *later* specs of the same key — handled on revisit above.
        node.component = cs
        selected[key] = cs
        order.append(cs)

        # CollectContext: merge the component's context contribution.
        for k, v in cs.context.items():
            if k in ctx and ctx[k] != v and not k.startswith("_"):
                # context clash across managers is also a conflict — learn it
                raise _Conflict(key, seen_specs[key] + [f"=={cs.version}"])
            ctx[k] = v

        for dep in cs.deps:
            child = Node(dep)
            node.children.append(child)
            queue.append(child)

    return Resolution(components=order, context=ctx, tree=root,
                      restarts=restart_idx, learned=dict(learned),
                      selected_by_key=selected)
