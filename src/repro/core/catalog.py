"""The built-in component catalog — the Uniform Component Registry content.

This is the analog of the paper's converted-package registry (§4.3): every
module of this framework is published as an immutable uniform component
``(M, n, v, e)`` with metadata deps ``D``, context contribution ``C``, and
environment requirements, so the lazy-builder can assemble a platform-
fitted container from a CIR's *direct* dependency declarations only.

Managers (the environment-manager analogs):
  model    — model-family assemblers (decoder-dense/-moe/-rwkv/-hybrid/...)
  kernel   — compute kernels: attention / moe-dispatch / wkv6 / ssm-scan /
             rmsnorm, each with env variants (tpu-pallas vs xla vs naive)
  parallel — sharding plans (tp / fsdp-tp / sp-decode / pipeline)
  runtime  — step builders (train-step / serve-step / request-batcher)
  opt      — optimizer (adamw, moment-precision env variants)
  data     — input pipelines
  env      — the interpreter/runtime analogs (os-base, runtime-base)
  asset    — weights + frontend stubs (virtual bytes, never materialized)

Wire sizes: code components carry their true source size; ``env`` and
``asset`` components carry documented real-world artifact sizes (jaxlib /
libtpu / CUDA wheel sizes; 2 bytes/param for bf16 weights) — these drive
the image-size / bandwidth benchmarks exactly like the paper's packages.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from ..configs import ARCHS
from ..configs.base import ArchConfig, FAMILY_MODEL_COMPONENT
from .component import DependencyItem as D
from .component import Requirement as R
from .component import UniformComponent as C
from .lazybuild import register_payload
from .registry import (UniformComponentRegistry, UniformComponentService,
                       UpstreamSource)
from .resolution import register_context_spec_hook


def _src_size(module) -> int:
    """True source bytes of a python module — the converted-code wire size."""
    try:
        import inspect
        return len(inspect.getsource(module).encode())
    except Exception:
        return 16 * 1024


# Documented real-world artifact sizes (bytes) for the env components:
#   cpu   : jaxlib-cpu wheel ≈ 120 MB
#   tpu   : jaxlib + libtpu ≈ 450 MB
#   gpu   : jaxlib + cuda12 + cudnn wheels ≈ 2.3 GB (torch-cu12 class)
_RUNTIME_BASE_SIZES = {
    "cpu-host": 120 * 2**20,
    "tpu-v5e": 450 * 2**20,
    "gpu-a100": 2300 * 2**20,
}
_OS_BASE_SIZE = 80 * 2**20          # debian-slim base layer analog
_FRONTEND_SIZES = {                  # bf16 param bytes of the real frontends
    "audio-frames": int(60e6) * 2,   # EnCodec-class audio encoder
    "vision-patches": int(675e6) * 2,  # Qwen2-VL ViT-class vision tower
}


# ===========================================================================
# Payloads — the executable bodies the converter produced
# ===========================================================================

@register_payload("model.decoder")
def _build_decoder(cfg: ArchConfig, context: Mapping[str, Any], bundle):
    """Model-family assembler: reads which kernel variants Algorithm 1
    selected (their context contributions) and composes the model."""
    from ..models import Variants, build_model
    v = Variants(
        attn_kernel=context.get("attn.impl", "lax-flash"),
        moe_impl=context.get("moe.impl", "grouped"),
        wkv_impl=context.get("wkv.impl", "chunked"),
        remat=context.get("remat", "full"),
        capacity_factor=float(context.get("moe.capacity", 1.25)),
        moe_combine=context.get("moe.combine", "f32"),
        moe_slot_dp=bool(context.get("moe.slot_dp", False)),
    )
    return build_model(cfg, v)


# -- kernels: payloads expose the impls and register platform variants ------

@register_payload("kernel.attention.naive")
def _k_attn_naive():
    from ..models.attention import naive_attention
    return naive_attention


@register_payload("kernel.attention.xla_flash")
def _k_attn_xla():
    from ..models.attention import lax_flash_attention
    return lax_flash_attention


@register_payload("kernel.attention.pallas")
def _k_attn_pallas():
    from ..kernels import pallas_attention
    return pallas_attention


@register_payload("kernel.wkv6.sequential")
def _k_wkv_seq():
    from ..models.ssm import wkv6_sequential
    return wkv6_sequential


@register_payload("kernel.wkv6.chunked")
def _k_wkv_chunk():
    from ..models.ssm import wkv6_chunked
    return wkv6_chunked


@register_payload("kernel.wkv6.pallas")
def _k_wkv_pallas():
    from ..kernels import pallas_wkv6
    return pallas_wkv6


@register_payload("kernel.moe.grouped")
def _k_moe_grouped():
    from ..models.ffn import moe_grouped
    return moe_grouped


@register_payload("kernel.moe.dense")
def _k_moe_dense():
    from ..models.ffn import moe_dense
    return moe_dense


@register_payload("kernel.ssm_scan.lax")
def _k_ssm():
    from ..models.ssm import mamba_block
    return mamba_block


@register_payload("kernel.rmsnorm.xla")
def _k_rms_xla():
    from ..models.common import rms_norm
    return rms_norm


@register_payload("kernel.rmsnorm.pallas")
def _k_rms_pallas():
    from ..kernels import pallas_rmsnorm
    return pallas_rmsnorm


# -- parallel plans ----------------------------------------------------------

@register_payload("parallel.pipeline")
def _pipeline_combinator():
    from ..models.pipeline import pipeline_apply
    return pipeline_apply


@register_payload("parallel.plan")
def _build_plan(rules_name: str, mesh):
    from ..models.sharding import RULE_SETS, ShardingPlan
    if mesh is None:
        return None
    return ShardingPlan(rules_name, mesh, RULE_SETS[rules_name](
        mesh.axis_names))


# -- runtime: train step -------------------------------------------------------

def _batch_logical_axes(cfg: ArchConfig, batch_shapes: Mapping[str, Any]):
    """Logical axes for every batch leaf (arch-aware)."""
    out = {}
    for k, v in batch_shapes.items():
        nd = len(v.shape) if hasattr(v, "shape") else jnp.ndim(v)
        if k == "positions" and nd == 3:
            out[k] = (None, "act_batch", None)
        elif k in ("embeds", "vis_embeds"):
            out[k] = ("act_batch", None, None)
        else:
            out[k] = ("act_batch",) + (None,) * (nd - 1)
    return out


def make_state_shardings(model, plan, moments: str = "f32"):
    """NamedSharding pytree for {'params', 'opt': {'step','m','v'}}."""
    from ..models.common import P as PSpec
    from ..models.sharding import zero1_axes
    from jax.sharding import NamedSharding, PartitionSpec

    def p_shard(p: PSpec):
        return plan.sharding(p.axes, p.shape)

    def m_shard(p: PSpec):
        if moments == "int8":
            # codes keep the PARAM's shape (blocks along the last dim), so
            # they inherit the param's exact sharding; scales drop the last
            # dim — no moment↔param resharding anywhere in the update.
            if not p.shape:
                repl0 = NamedSharding(plan.mesh, PartitionSpec())
                return {"q": repl0, "s": repl0}
            nblk = (p.shape[-1] + 127) // 128
            return {"q": plan.sharding(p.axes, p.shape),
                    "s": plan.sharding(p.axes[:-1] + (None,),
                                       p.shape[:-1] + (nblk,))}
        return plan.sharding(zero1_axes(p.axes, plan, p.shape), p.shape)

    is_p = lambda x: isinstance(x, PSpec)
    params = jax.tree.map(p_shard, model.specs, is_leaf=is_p)
    moments_sh = jax.tree.map(m_shard, model.specs, is_leaf=is_p)
    repl = NamedSharding(plan.mesh, PartitionSpec())
    return {"params": params,
            "opt": {"step": repl, "m": moments_sh, "v": moments_sh}}


def make_batch_shardings(cfg, plan, batch_shapes):
    ax = _batch_logical_axes(cfg, batch_shapes)
    return {k: plan.sharding(a, tuple(batch_shapes[k].shape))
            for k, a in ax.items()}


@register_payload("runtime.train_step")
def _build_train_entry(model, cfg: ArchConfig, context, bundle, mesh=None):
    from ..optim import (AdamWConfig, TrainStepConfig, adamw_init,
                         build_train_step, cosine_schedule, ef_compress_init)
    from ..models.sharding import use_plan

    plan = _build_plan(context.get("plan.rules", "tp"), mesh)
    adamw = AdamWConfig(
        lr=cosine_schedule(float(context.get("lr", 3e-4)),
                           int(context.get("warmup", 100)),
                           int(context.get("total_steps", 10000))),
        moments=context.get("opt.moments", "f32"))
    ts = TrainStepConfig(
        microbatch=int(context.get("grad_accum", 0) or 0),
        compress=bool(context.get("train.compress", False)),
        adamw=adamw)
    raw_step = build_train_step(model, ts)

    def train_step(state, batch):
        with use_plan(plan):
            return raw_step(state, batch)

    def init_state(key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        params = model.init(key)
        state = {"params": params, "opt": adamw_init(params, ts.adamw)}
        if ts.compress:
            state["ef_err"] = ef_compress_init(params)
        return state

    def state_shardings():
        sh = make_state_shardings(model, plan, moments=ts.adamw.moments)
        if ts.compress:
            sh["ef_err"] = make_state_shardings(model, plan)["opt"]["m"]
        return sh

    return {
        "train_step": train_step,
        "init_state": init_state,
        "plan": plan,
        "ts_cfg": ts,
        "state_shardings": state_shardings,
        "batch_shardings": functools.partial(make_batch_shardings, cfg, plan),
    }


# -- runtime: serve step ---------------------------------------------------------

@register_payload("runtime.serve_step")
def _build_serve_entry(model, cfg: ArchConfig, context, bundle, mesh=None):
    from ..models.sharding import use_plan
    from ..models.common import axes_tree

    plan = _build_plan(context.get("plan.rules", "tp"), mesh)

    def prefill(params, batch, cache):
        with use_plan(plan):
            return model.prefill(params, batch, cache)

    def decode_step(params, tokens, positions, cache, cache_pos):
        with use_plan(plan):
            return model.decode_step(params, tokens, positions, cache,
                                     cache_pos)

    def cache_shardings(batch_size: int, max_seq: int):
        from ..models.common import P as PSpec
        return jax.tree.map(
            lambda p: plan.sharding(p.axes, p.shape),
            model.cache_specs(batch_size, max_seq),
            is_leaf=lambda x: isinstance(x, PSpec))

    def param_shardings():
        from ..models.common import P as PSpec
        return jax.tree.map(lambda p: plan.sharding(p.axes), model.specs,
                            is_leaf=lambda x: isinstance(x, PSpec))

    return {
        "prefill": prefill,
        "decode_step": decode_step,
        "plan": plan,
        "cache_shardings": cache_shardings,
        "param_shardings": param_shardings,
        "batch_shardings": functools.partial(make_batch_shardings, cfg, plan),
    }


@register_payload("runtime.request_batcher")
def _build_batcher(model, cfg: ArchConfig, context, bundle, mesh=None):
    from ..serving import ServingEngine

    def make_engine(params, **kw):
        return ServingEngine(model, params, **kw)

    return {"make_engine": make_engine}


# -- data / opt / assets -----------------------------------------------------------

@register_payload("data.synthetic")
def _build_data(model, cfg: ArchConfig, context, bundle, mesh=None):
    from ..data import batch_for_arch

    def batch_fn(seq_len, global_batch, step=0, seed=0, host=0, num_hosts=1):
        return batch_for_arch(cfg, seq_len, global_batch, step=step,
                              seed=seed, host=host, num_hosts=num_hosts)

    return {"batch_fn": batch_fn}


@register_payload("opt.adamw")
def _opt_adamw():
    from .. import optim
    return optim


@register_payload("asset.weights")
def _asset_weights():
    return None          # virtual bytes only — weights are lazily init'd


@register_payload("asset.frontend")
def _asset_frontend():
    return None


@register_payload("env.base")
def _env_base():
    return None


# ===========================================================================
# The registry content
# ===========================================================================

def _model_components() -> List[C]:
    from .. import models
    out: List[C] = []
    code_sz = _src_size(models.transformer if hasattr(models, "transformer")
                        else models)
    kernel_deps = {
        "decoder-dense": [D("kernel", "attention", "~=1.0")],
        "decoder-moe": [D("kernel", "attention", "~=1.0"),
                        D("kernel", "moe-dispatch", "any")],
        "decoder-rwkv": [D("kernel", "wkv6", "~=1.0")],
        "decoder-hybrid": [D("kernel", "attention", "~=1.0"),
                           D("kernel", "ssm-scan", "any"),
                           D("kernel", "moe-dispatch", "any")],
        "decoder-audio": [D("kernel", "attention", "~=1.0")],
        "decoder-vlm": [D("kernel", "attention", "~=1.0")],
    }
    for name, kdeps in kernel_deps.items():
        deps = tuple(kdeps) + (
            D("parallel", "plan", "any"),
            D("kernel", "rmsnorm", "any"),
            D("env", "runtime-base", "any"),
        )
        for version in ("1.0.0", "1.1.0"):
            out.append(C(
                manager="model", name=name, version=version, env="generic",
                deps=deps,
                context={"model.family": name, "kernel.api": "1"},
                payload="model.decoder", size_bytes=code_sz,
                perf_score=1.0 + (0.2 if version == "1.1.0" else 0.0),
                provides=("model",),
            ))
    return out


def _kernel_components() -> List[C]:
    from .. import kernels as kmod
    from ..models import attention as amod, ssm as smod, ffn as fmod
    ksz = _src_size(kmod.flash_attention) if hasattr(kmod, "flash_attention") \
        else 64 * 1024
    out: List[C] = []
    base_dep = (D("env", "runtime-base", "any"),)

    # attention — four environment variants across two versions
    for version in ("1.0.0", "1.1.0"):
        out += [
            C("kernel", "attention", version, "tpu-pallas",
              deps=base_dep, context={"attn.impl": "pallas"},
              requires=(R("vendor", "eq", "google"),
                        R("interpret", "false")),
              payload="kernel.attention.pallas",
              size_bytes=_src_size(__import__(
                  "repro.kernels.flash_attention", fromlist=["x"])),
              perf_score=3.0, provides=("attention",)),
            C("kernel", "attention", version, "pallas-interpret",
              deps=base_dep, context={"attn.impl": "pallas"},
              requires=(R("interpret", "true"),),
              payload="kernel.attention.pallas",
              size_bytes=ksz, perf_score=0.6, provides=("attention",)),
            C("kernel", "attention", version, "xla-flash",
              deps=base_dep, context={"attn.impl": "lax-flash"},
              payload="kernel.attention.xla_flash",
              size_bytes=_src_size(amod), perf_score=2.0,
              provides=("attention",)),
            C("kernel", "attention", version, "naive",
              deps=base_dep, context={"attn.impl": "naive"},
              payload="kernel.attention.naive",
              size_bytes=8 * 1024, perf_score=0.4, provides=("attention",)),
        ]

    # moe dispatch
    out += [
        C("kernel", "moe-dispatch", "1.0.0", "grouped-gemm",
          deps=base_dep, context={"moe.impl": "grouped"},
          payload="kernel.moe.grouped", size_bytes=_src_size(fmod),
          perf_score=2.0, provides=("moe",)),
        C("kernel", "moe-dispatch", "1.0.0", "dense-oracle",
          deps=base_dep, context={"moe.impl": "dense"},
          requires=(R("mesh.chips", "le", 2),),
          payload="kernel.moe.dense", size_bytes=16 * 1024,
          perf_score=2.5, provides=("moe",)),
    ]

    # wkv6
    out += [
        C("kernel", "wkv6", "1.0.0", "tpu-pallas",
          deps=base_dep, context={"wkv.impl": "pallas"},
          requires=(R("vendor", "eq", "google"), R("interpret", "false")),
          payload="kernel.wkv6.pallas",
          size_bytes=_src_size(__import__(
              "repro.kernels.rwkv6_scan", fromlist=["x"])),
          perf_score=3.0, provides=("wkv",)),
        C("kernel", "wkv6", "1.0.0", "pallas-interpret",
          deps=base_dep, context={"wkv.impl": "pallas"},
          requires=(R("interpret", "true"),),
          payload="kernel.wkv6.pallas", size_bytes=ksz,
          perf_score=0.6, provides=("wkv",)),
        C("kernel", "wkv6", "1.0.0", "chunked-lax",
          deps=base_dep, context={"wkv.impl": "chunked"},
          payload="kernel.wkv6.chunked", size_bytes=_src_size(smod),
          perf_score=2.0, provides=("wkv",)),
        C("kernel", "wkv6", "1.0.0", "sequential",
          deps=base_dep, context={"wkv.impl": "sequential"},
          payload="kernel.wkv6.sequential", size_bytes=8 * 1024,
          perf_score=0.4, provides=("wkv",)),
    ]

    # mamba scan + rmsnorm
    out += [
        C("kernel", "ssm-scan", "1.0.0", "lax-scan",
          deps=base_dep, context={"ssm.impl": "lax"},
          payload="kernel.ssm_scan.lax", size_bytes=_src_size(smod),
          perf_score=1.0, provides=("ssm",)),
        C("kernel", "rmsnorm", "1.0.0", "fused-pallas",
          deps=base_dep, requires=(R("vendor", "eq", "google"),
                                   R("interpret", "false")),
          payload="kernel.rmsnorm.pallas", size_bytes=16 * 1024,
          perf_score=2.0, provides=("norm",)),
        C("kernel", "rmsnorm", "1.0.0", "xla",
          deps=base_dep, payload="kernel.rmsnorm.xla",
          size_bytes=8 * 1024, perf_score=1.0, provides=("norm",)),
    ]
    return out


def _parallel_components() -> List[C]:
    from ..models import sharding as shmod
    sz = _src_size(shmod)
    return [
        C("parallel", "plan", "1.0.0", "fsdp-tp",
          context={"plan.rules": "fsdp-tp"},
          requires=(R("mesh.data", "ge", 2),),
          payload="parallel.plan", size_bytes=sz, perf_score=2.5),
        C("parallel", "plan", "1.0.0", "tp",
          context={"plan.rules": "tp"},
          payload="parallel.plan", size_bytes=sz, perf_score=1.5),
        C("parallel", "plan", "1.0.0", "decode",
          context={"plan.rules": "decode"},
          requires=(R("workload", "eq", "decode"),),
          payload="parallel.plan", size_bytes=sz, perf_score=3.0),
        C("parallel", "plan", "1.1.0", "prefill-sp",
          context={"plan.rules": "prefill-sp"},
          requires=(R("workload", "eq", "prefill-sp"),),
          payload="parallel.plan", size_bytes=sz, perf_score=3.0),
        C("parallel", "plan", "1.1.0", "dp-replicated",
          context={"plan.rules": "dp"},
          requires=(R("plan.force", "eq", "dp"),),
          payload="parallel.plan", size_bytes=sz, perf_score=3.5),
        C("parallel", "pipeline", "1.0.0", "gpipe",
          context={"pp.schedule": "gpipe"},
          requires=(R("workload", "eq", "pipeline"),),
          payload="parallel.pipeline", size_bytes=sz, perf_score=2.0),
        C("parallel", "plan", "1.0.0", "sp-decode",
          context={"plan.rules": "sp-decode"},
          requires=(R("workload", "eq", "long-decode"),),
          payload="parallel.plan", size_bytes=sz, perf_score=3.0),
    ]


def _runtime_components() -> List[C]:
    from .. import optim as omod, serving as svmod, data as dmod
    opt_dep = (D("opt", "adamw", "any"), D("env", "runtime-base", "any"))
    return [
        C("runtime", "train-step", "1.0.0", "standard",
          deps=opt_dep, payload="runtime.train_step",
          size_bytes=_src_size(omod), perf_score=1.5),
        C("runtime", "train-step", "1.0.0", "compressed-dci",
          deps=opt_dep, context={"train.compress": True},
          requires=(R("mesh.pod", "ge", 2),),
          payload="runtime.train_step", size_bytes=_src_size(omod),
          perf_score=2.5),
        C("runtime", "serve-step", "1.0.0", "standard",
          deps=(D("env", "runtime-base", "any"),),
          payload="runtime.serve_step", size_bytes=_src_size(svmod),
          perf_score=1.5),
        C("runtime", "request-batcher", "1.0.0", "slot-continuous",
          deps=(D("runtime", "serve-step", "any"),),
          payload="runtime.request_batcher", size_bytes=_src_size(svmod),
          perf_score=1.5),
        C("opt", "adamw", "1.0.0", "f32-moments",
          payload="opt.adamw", size_bytes=_src_size(omod), perf_score=1.5,
          context={"opt.moments": "f32"},
          requires=(R("hbm.per_chip", "ge", 32 * 2**30),)),
        C("opt", "adamw", "1.0.0", "bf16-moments",
          payload="opt.adamw", size_bytes=_src_size(omod), perf_score=1.2,
          context={"opt.moments": "bf16"}),
        C("opt", "adamw", "1.1.0", "int8-moments",
          payload="opt.adamw", size_bytes=_src_size(omod), perf_score=2.0,
          context={"opt.moments": "int8"},
          requires=(R("opt.int8", "true"),)),   # opt-in: HBM-starved giants
        C("data", "pipeline-synthetic", "1.0.0", "standard",
          payload="data.synthetic", size_bytes=_src_size(dmod),
          perf_score=1.0),
    ]


def _env_components() -> List[C]:
    out = [C("env", "os-base", "12.0", "any", payload="env.base",
             size_bytes=_OS_BASE_SIZE, perf_score=1.0)]
    for chip, size in _RUNTIME_BASE_SIZES.items():
        out.append(C(
            "env", "runtime-base", "0.8.2", chip,
            deps=(D("env", "os-base", "any"),),
            context={"runtime.platform": chip},
            requires=(R("chip", "eq", chip),),
            payload="env.base", size_bytes=size, perf_score=1.0))
    return out


def _asset_components() -> List[C]:
    """Weights (exact virtual bytes) + frontend stubs, as upstream-converted
    components — these come in via the UpstreamSource path to exercise the
    registry→upstream fallback (paper Fig. 5)."""
    out: List[C] = []
    for arch_id, cfg in ARCHS.items():
        n = cfg.param_count()
        out.append(C(
            "asset", f"weights-{arch_id}", "2025.12.1", "bf16",
            payload="asset.weights", size_bytes=2 * n,
            context={f"weights.{arch_id}": "2025.12.1"},
            meta={"params": n}, perf_score=1.0))
    for fe, size in _FRONTEND_SIZES.items():
        out.append(C(
            "asset", f"frontend-{fe}", "1.0.0", "bf16",
            payload="asset.frontend", size_bytes=size, perf_score=1.0))
    return out


# -- context-spec hooks (the paper's M.getSpec(C)) ---------------------------

def _kernel_spec_hook(name: str, ctx: Mapping[str, Any]) -> Optional[str]:
    """Models pin the kernel API major version through the building context
    (cross-manager constraint flow, like pip's python-version pins)."""
    api = ctx.get("kernel.api")
    if api and name in ("attention", "wkv6"):
        return f"~={api}.0"
    return None


register_context_spec_hook("kernel", _kernel_spec_hook)


# ===========================================================================
# Service construction
# ===========================================================================

def builtin_components() -> List[C]:
    return (_model_components() + _kernel_components()
            + _parallel_components() + _runtime_components()
            + _env_components())


def build_service(with_assets_upstream: bool = True
                  ) -> UniformComponentService:
    """Fresh registry + service.  Asset components live behind an
    UpstreamSource so the first request exercises on-demand conversion."""
    registry = UniformComponentRegistry()
    registry.register_all(builtin_components())
    upstreams = []
    if with_assets_upstream:
        upstreams.append(UpstreamSource(
            name="asset-hub",
            lister=lambda: [None],
            converter=lambda _raw: _asset_components()))
    else:
        registry.register_all(_asset_components())
    return UniformComponentService(registry, upstreams)


_DEFAULT: Optional[UniformComponentService] = None


def default_service() -> UniformComponentService:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = build_service()
    return _DEFAULT
