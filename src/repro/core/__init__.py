"""repro.core — the paper's contribution: CIR + lazy-build infrastructure."""
from .component import (DependencyItem, Requirement, Specifier,  # noqa: F401
                        UniformComponent, Version)
from .registry import (RegistryError, UniformComponentRegistry,  # noqa: F401
                       UniformComponentService, UpstreamSource)
from .selection import (DeployabilityEvaluator, SelectionError,  # noqa: F401
                        uniform_component_selection, version_select)
from .resolution import (Resolution, ResolutionError,  # noqa: F401
                         resolution_from_pins, uniform_dependency_resolution)
from .spec import (CHIPS, CPU_HOST, GPU_A100, TPU_V5E, SpecSheet,  # noqa: F401
                   cpu_smoke, gpu_server, probe_host, tpu_multi_pod,
                   tpu_single_pod)
from .store import (Chunk, EVICTION_POLICIES,  # noqa: F401
                    LifecycleStats, LocalComponentStore,
                    SPEC_LEASE_PREFIX, StoreStats, component_pieces)
from .chunkstore import (ChunkStats, ChunkedComponentStore,  # noqa: F401
                         FetchPlan)
from .cir import CIR, PreBuilder  # noqa: F401
from .integrity import (ATTESTATION_VERSION, Attestation,  # noqa: F401
                        AttestationError, ED25519_AVAILABLE, Ed25519Signer,
                        HMACSigner, Signer, attest, canonical_manifest,
                        make_sbom, manifest_digest, verify_attestation,
                        write_sbom)
from .simnet import (FAULT_KINDS, UPSTREAM, Fault,  # noqa: F401
                     FaultError, FaultPlan, LinkDownError, NodeDownError,
                     SimClock, SimNetwork, SimTransport, WallClockTransport)
from .orchestrator import (STAGES, BuildGraph,  # noqa: F401
                           BuildOrchestrator, ComponentReadiness, Lifecycle)
from .compilecache import (COMPILED_MANAGER, COMPILE_VERSION_SALT,  # noqa: F401
                           CompileCache, CompileCacheStats,
                           CompiledArtifact, artifact_component,
                           compile_cache_key, legacy_compile_cache_key)
from .irmodule import (AUTOTUNE_MANAGER, IR_MANAGER,  # noqa: F401
                       IR_VERSION_SALT, autotune_component,
                       ir_module_component, ir_module_digest)
from .lazybuild import (BuildPlan, BuildPlanCache, BuildReport,  # noqa: F401
                        ComponentBundle, ContainerInstance, FetchEngine,
                        LazyBuilder, Lockfile, PlanCacheStats,
                        register_payload)
from .snapshot import (InstanceSnapshot, restore_instance,  # noqa: F401
                       snapshot_instance)
