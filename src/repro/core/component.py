"""Uniform components, versions, specifiers and dependency items.

Every component is uniquely identified by ``(M, n, v, e)`` — manager, name,
version, environment-variant (paper §3.2).  Metadata carries the dependency
items ``D`` and the building-context contribution ``C``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Versions — PEP440-flavoured but deliberately small: N(.N)* with optional
# pre-release tag.  Enough to express every upstream scheme we manage.
# ---------------------------------------------------------------------------

_VERSION_RE = re.compile(r"^\s*v?(\d+(?:\.\d+)*)(?:[-.]?(a|b|rc|dev)\.?(\d*))?\s*$")


@dataclasses.dataclass(frozen=True, order=False)
class Version:
    release: Tuple[int, ...]
    pre: Tuple[str, int] = ()  # type: ignore[assignment]

    @staticmethod
    def parse(s: str) -> "Version":
        m = _VERSION_RE.match(str(s))
        if not m:
            raise ValueError(f"unparseable version: {s!r}")
        release = tuple(int(p) for p in m.group(1).split("."))
        pre: Tuple = ()
        if m.group(2):
            pre = (m.group(2), int(m.group(3) or 0))
        return Version(release, pre)

    def _key(self, width: int = 8):
        rel = self.release + (0,) * (width - len(self.release))
        # pre-releases sort before the release itself
        pre = self.pre if self.pre else ("z", 0)
        return (rel, pre)

    def __lt__(self, other: "Version") -> bool:  # type: ignore[override]
        return self._key() < other._key()

    def __le__(self, other: "Version") -> bool:  # type: ignore[override]
        return self._key() <= other._key()

    def __gt__(self, other: "Version") -> bool:  # type: ignore[override]
        return self._key() > other._key()

    def __ge__(self, other: "Version") -> bool:  # type: ignore[override]
        return self._key() >= other._key()

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Version) and self._key() == other._key()

    def __hash__(self) -> int:
        return hash(self._key())

    def truncated(self, n: int) -> Tuple[int, ...]:
        return self.release[:n]

    def __str__(self) -> str:
        s = ".".join(str(p) for p in self.release)
        if self.pre:
            s += f"{self.pre[0]}{self.pre[1]}"
        return s


# ---------------------------------------------------------------------------
# Specifiers: ``>=1.2``, ``~=2.0``, ``==1.2.3``, ``!=1.3``, ``<2``, ``latest``,
# ``any`` and comma-separated conjunctions (``>=1.0,<2.0``).
# ---------------------------------------------------------------------------

_CLAUSE_RE = re.compile(r"^(==|!=|>=|<=|~=|>|<|=)?\s*(.+)$")


class Specifier:
    def __init__(self, text: str):
        self.text = (text or "any").strip() or "any"
        self._clauses: List[Tuple[str, Optional[Version]]] = []
        for raw in self.text.split(","):
            raw = raw.strip()
            if not raw:
                continue
            low = raw.lower()
            if low in ("any", "*"):
                self._clauses.append(("any", None))
                continue
            if low == "latest":
                self._clauses.append(("latest", None))
                continue
            m = _CLAUSE_RE.match(raw)
            if not m:
                raise ValueError(f"bad specifier clause: {raw!r}")
            op = m.group(1) or "=="
            if op == "=":
                op = "=="
            self._clauses.append((op, Version.parse(m.group(2))))

    @property
    def wants_latest(self) -> bool:
        return any(op == "latest" for op, _ in self._clauses)

    def matches(self, v: Version) -> bool:
        for op, ref in self._clauses:
            if op in ("any", "latest"):
                continue
            assert ref is not None
            if op == "==":
                # ``==1.2`` matches 1.2.* (prefix match, PEP440-style)
                if v.truncated(len(ref.release)) != ref.release or (
                        ref.pre and v.pre != ref.pre):
                    return False
            elif op == "!=":
                if v.truncated(len(ref.release)) == ref.release:
                    return False
            elif op == ">=":
                if not v >= ref:
                    return False
            elif op == "<=":
                if not v <= ref:
                    return False
            elif op == ">":
                if not v > ref:
                    return False
            elif op == "<":
                if not v < ref:
                    return False
            elif op == "~=":
                # compatible release: >=ref and ==ref truncated by one
                if not v >= ref:
                    return False
                if v.truncated(max(1, len(ref.release) - 1)) != ref.release[:-1]:
                    return False
        return True

    def intersect_text(self, other: "Specifier") -> str:
        """Conjunction of two specifiers (used by conflict resolution)."""
        parts = [p for p in (self.text, other.text)
                 if p not in ("any", "*")]
        return ",".join(parts) if parts else "any"

    def __repr__(self) -> str:
        return f"Specifier({self.text!r})"

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Specifier) and self.text == other.text

    def __hash__(self) -> int:
        return hash(self.text)


# ---------------------------------------------------------------------------
# Dependency items d = (M, n, specifier)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DependencyItem:
    manager: str              # component manager M ("layer", "kernel", ...)
    name: str                 # n
    specifier: str = "any"    # raw text

    @property
    def spec(self) -> Specifier:
        return Specifier(self.specifier)

    def key(self) -> Tuple[str, str]:
        return (self.manager, self.name)

    def __str__(self) -> str:
        return f"[{self.manager}] {self.name} [{self.specifier}]"


# ---------------------------------------------------------------------------
# Environment variants + requirements
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Requirement:
    """A predicate over the specSheet context, e.g. ('chip', 'in', ['tpu-v5e']).

    ops: eq, ne, in, ge, le, has (membership of value in a context list),
         true/false (boolean context keys).
    """
    key: str
    op: str
    value: Any = None

    def satisfied(self, ctx: Mapping[str, Any]) -> bool:
        have = ctx.get(self.key)
        if self.op == "eq":
            return have == self.value
        if self.op == "ne":
            return have != self.value
        if self.op == "in":
            return have in self.value
        if self.op == "ge":
            return have is not None and have >= self.value
        if self.op == "le":
            return have is not None and have <= self.value
        if self.op == "has":
            return isinstance(have, (list, tuple, set)) and self.value in have
        if self.op == "true":
            return bool(have)
        if self.op == "false":
            return not bool(have)
        raise ValueError(f"unknown requirement op {self.op}")

    def to_json(self) -> List[Any]:
        return [self.key, self.op, self.value]


# ---------------------------------------------------------------------------
# UniformComponent
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UniformComponent:
    """Immutable building block (paper §3.2).

    ``payload`` is the factory reference: the name of a python callable in
    the in-process catalog (the converter output analog).  ``size_bytes`` is
    the component's wire size — real bytes for asset components (weights),
    measured source+metadata bytes for module components.
    """
    manager: str
    name: str
    version: str
    env: str                                   # environment-variant id
    deps: Tuple[DependencyItem, ...] = ()
    context: Dict[str, Any] = dataclasses.field(default_factory=dict)
    requires: Tuple[Requirement, ...] = ()
    provides: Tuple[str, ...] = ()             # capability tags
    payload: str = ""                          # catalog factory reference
    size_bytes: int = 0
    perf_score: float = 1.0                    # relative exec-perf rank in-family
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- identity ------------------------------------------------------------
    @property
    def vkey(self) -> Version:
        return Version.parse(self.version)

    def ident(self) -> Tuple[str, str, str, str]:
        return (self.manager, self.name, self.version, self.env)

    def ident_str(self) -> str:
        return f"{self.manager}:{self.name}=={self.version}@{self.env}"

    def digest(self) -> str:
        blob = json.dumps({
            "id": self.ident(),
            "deps": [[d.manager, d.name, d.specifier] for d in self.deps],
            "context": self.context,
            "payload": self.payload,
            "provides": list(self.provides),
        }, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()

    def env_satisfied(self, ctx: Mapping[str, Any]) -> bool:
        return all(r.satisfied(ctx) for r in self.requires)

    # -- (de)serialization — the 'converter' archive format -----------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "manager": self.manager, "name": self.name,
            "version": self.version, "env": self.env,
            "deps": [[d.manager, d.name, d.specifier] for d in self.deps],
            "context": self.context,
            "requires": [r.to_json() for r in self.requires],
            "provides": list(self.provides),
            "payload": self.payload,
            "size_bytes": self.size_bytes,
            "perf_score": self.perf_score,
            "meta": self.meta,
        }

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "UniformComponent":
        return UniformComponent(
            manager=d["manager"], name=d["name"], version=d["version"],
            env=d["env"],
            deps=tuple(DependencyItem(*x) for x in d.get("deps", ())),
            context=dict(d.get("context", {})),
            requires=tuple(Requirement(*x) for x in d.get("requires", ())),
            provides=tuple(d.get("provides", ())),
            payload=d.get("payload", ""),
            size_bytes=int(d.get("size_bytes", 0)),
            perf_score=float(d.get("perf_score", 1.0)),
            meta=dict(d.get("meta", {})),
        )


def component_sort_key(c: UniformComponent):
    return (c.vkey, c.env)
