"""Batched serving engine: slot-based continuous batching.

The engine owns ``num_slots`` cache slots.  Each engine tick:
  1. admit — free slots are filled from the request queue; the prompt is
     prefilled (padded to a fixed bucket so the compiled prefill is reused)
     and its cache scattered into the slot;
  2. decode — ONE fused decode step advances *all* live slots together,
     each at its own depth (vector ``cache_pos``);
  3. retire — slots that hit EOS/max_tokens emit a finished response.

Everything jitted is shape-stable: (num_slots, 1) decode, a fixed set of
prefill buckets — no recompiles in steady state.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0             # 0 = greedy
    submitted_at: float = 0.0


@dataclasses.dataclass
class Response:
    rid: int
    tokens: List[int]
    prompt_len: int
    queued_s: float
    prefill_s: float
    decode_s: float


class ServingEngine:
    def __init__(self, model, params, *, num_slots: int = 8,
                 max_seq: int = 1024,
                 prefill_buckets: Sequence[int] = (64, 256),
                 eos_id: int = -1, rng_seed: int = 0):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.prefill_buckets = sorted(prefill_buckets)
        self.eos_id = eos_id
        self.cfg = model.cfg

        self.cache = model.init_cache(num_slots, max_seq)
        self.queue: deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_pos = np.zeros(num_slots, np.int32)       # next write pos
        self.slot_out: List[List[int]] = [[] for _ in range(num_slots)]
        self.slot_t0 = np.zeros(num_slots, np.float64)
        self.slot_tprefill = np.zeros(num_slots, np.float64)
        self.finished: List[Response] = []
        self._next_tokens = np.zeros(num_slots, np.int32)
        self._key = jax.random.PRNGKey(rng_seed)
        self._ticks = 0

        # jitted single-slot prefill (per bucket) and fused decode
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("bucket",))
        self._decode = jax.jit(self._decode_impl)

    # -- jitted bodies ------------------------------------------------------
    def _prefill_impl(self, params, tokens, length, bucket: int):
        """tokens: (1, bucket); length: scalar prompt length.
        Returns (next_token_logits (1, v), cache_b1)."""
        m = self.model
        cache = m.init_cache(1, self.max_seq)
        pos = jnp.arange(bucket, dtype=jnp.int32)[None]
        if self.cfg.mrope_sections:
            pos3 = jnp.broadcast_to(pos, (3, 1, bucket))
            batch = {"tokens": tokens, "positions": pos3}
        else:
            batch = {"tokens": tokens, "positions": pos}
        if self.cfg.family == "audio-lm":
            # serve path embeds codebook tokens via the embedding table
            from .models.common import sinusoidal_pos
            e = params["embed"]["tok"][tokens]
            e = e + sinusoidal_pos(pos, self.cfg.d_model).astype(e.dtype)
        else:
            e = m.embed(params, batch)
        logits, _, cache, _ = m.logits_fn(params, e, batch["positions"],
                                          cache, 0)
        last = jnp.take_along_axis(
            logits, (length - 1)[None, None, None].astype(jnp.int32)
            if jnp.ndim(length) == 0 else length[:, None, None], axis=1)
        return last[:, 0, :], cache

    def _decode_impl(self, params, cache, tokens, positions, live, key,
                     temps):
        """tokens: (slots,); positions: (slots,); live: (slots,) bool."""
        m = self.model
        toks = tokens[:, None]
        pos = positions[:, None]
        if self.cfg.mrope_sections:
            pos_in = jnp.broadcast_to(pos, (3,) + pos.shape)
        else:
            pos_in = pos
        logits, new_cache = m.decode_step(params, toks, pos_in, cache,
                                          positions)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(key, logits.shape, minval=1e-9, maxval=1.0)))
        sampled = jnp.argmax(
            logits / jnp.maximum(temps[:, None], 1e-6) + gumbel,
            axis=-1).astype(jnp.int32)
        next_tok = jnp.where(temps > 0, sampled, greedy)
        # dead slots must not corrupt their cache position: they decode into
        # position max_seq-1 and their token is ignored on the host.
        return next_tok, new_cache

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               temperature: float = 0.0) -> int:
        rid = len(self.finished) + len(self.queue) + sum(
            r is not None for r in self.slot_req)
        self.queue.append(Request(
            rid=rid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens, temperature=temperature,
            submitted_at=time.perf_counter()))
        return rid

    def _bucket_for(self, n: int) -> int:
        if self.cfg.family in ("ssm-lm", "hybrid-lm"):
            # recurrent state must not integrate padding junk: exact-length
            # prefill (one compile per distinct prompt length)
            return n
        for b in self.prefill_buckets:
            if n <= b:
                return b
        return self.prefill_buckets[-1]

    def _admit(self) -> None:
        for slot in range(self.num_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            t0 = time.perf_counter()
            n = len(req.prompt)
            bucket = self._bucket_for(n)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :n] = req.prompt[:bucket]
            logits, cache1 = self._prefill(
                self.params, jnp.asarray(toks),
                jnp.asarray(min(n, bucket), jnp.int32), bucket=bucket)
            # scatter the prefilled cache into this slot (batch axis = 1,
            # because stacked cache leaves are (layers, batch, ...))
            self.cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1),
                self.cache, cache1)
            first = int(jax.device_get(jnp.argmax(logits[0])))
            self.slot_req[slot] = req
            self.slot_pos[slot] = min(n, bucket)
            self.slot_out[slot] = [first]
            self._next_tokens[slot] = first
            self.slot_t0[slot] = req.submitted_at
            self.slot_tprefill[slot] = time.perf_counter() - t0

    def _retire(self) -> None:
        now = time.perf_counter()
        for slot in range(self.num_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            out = self.slot_out[slot]
            done = len(out) >= req.max_new_tokens or (
                self.eos_id >= 0 and out and out[-1] == self.eos_id)
            if done or int(self.slot_pos[slot]) >= self.max_seq - 1:
                self.finished.append(Response(
                    rid=req.rid, tokens=list(out),
                    prompt_len=len(req.prompt),
                    queued_s=now - req.submitted_at,
                    prefill_s=float(self.slot_tprefill[slot]),
                    decode_s=now - self.slot_t0[slot]))
                self.slot_req[slot] = None
                self.slot_out[slot] = []

    def tick(self) -> int:
        """One engine iteration; returns number of live slots decoded."""
        self._admit()
        self._retire()          # a 1-token request is done after prefill
        self._admit()
        live = np.array([r is not None for r in self.slot_req])
        if not live.any():
            return 0
        positions = np.where(live, self.slot_pos, self.max_seq - 1) \
            .astype(np.int32)
        temps = np.array([
            (r.temperature if r is not None else 0.0)
            for r in self.slot_req], np.float32)
        self._key, sub = jax.random.split(self._key)
        next_tok, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self._next_tokens),
            jnp.asarray(positions), jnp.asarray(live), sub,
            jnp.asarray(temps))
        next_tok = np.asarray(jax.device_get(next_tok))
        for slot in range(self.num_slots):
            if live[slot]:
                self.slot_out[slot].append(int(next_tok[slot]))
                self.slot_pos[slot] += 1
                self._next_tokens[slot] = next_tok[slot]
        self._ticks += 1
        self._retire()
        return int(live.sum())

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Response]:
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and max_ticks > 0:
            self.tick()
            max_ticks -= 1
        return self.finished
